//! Golden bit-exact IEEE-754 reference: multiply, add, and fused
//! multiply-add over raw bit patterns, in all four rounding modes.
//!
//! This is the *specification* the generated datapaths are tested against
//! (and, transitively, what the Pallas kernel and the AOT artifact are
//! checked against through the coordinator). It computes with exact
//! integer significand arithmetic (`u128` holds the 106-bit DP product
//! with room for alignment guards), then defers to
//! [`crate::arch::rounding::round_to_format`].
//!
//! The FMAC operation implemented is `a*b + c` — the paper's FMAC units
//! compute exactly this, with the FMA units rounding once and the CMA
//! units rounding after the multiply and again after the add (see
//! [`crate::arch::cma`]).

use super::fp::{bitlen128, decode, Class, Decoded, Format};
use super::rounding::{round_to_format, Flags, RoundMode, Rounded};

/// An exact unpacked finite value `(-1)^sign · sig · 2^exp` with a sticky
/// marker for discarded low-order bits (`value + (-1)^sign·ε`,
/// `0 ≤ ε < 2^exp`).
#[derive(Debug, Clone, Copy)]
pub struct Exact {
    pub sign: bool,
    pub exp: i32,
    pub sig: u128,
    pub sticky: bool,
}

impl Exact {
    /// Lift a decoded operand (finite classes only).
    pub fn from_decoded(d: &Decoded) -> Exact {
        Exact { sign: d.sign, exp: d.exp, sig: d.sig as u128, sticky: false }
    }

    /// Position of the value's MSB: value ∈ [2^(npos-1), 2^npos). Zero-sig
    /// values return i32::MIN.
    #[inline]
    pub fn npos(&self) -> i32 {
        if self.sig == 0 {
            i32::MIN
        } else {
            self.exp + bitlen128(self.sig) as i32
        }
    }
}

/// Exact product of two finite decoded operands (never overflows u128:
/// 53+53 = 106 bits).
pub fn mul_exact(a: &Decoded, b: &Decoded) -> Exact {
    Exact {
        sign: a.sign ^ b.sign,
        exp: a.exp + b.exp,
        sig: a.sig as u128 * b.sig as u128,
        sticky: false,
    }
}

/// Exact (sticky-summarized) sum of two unpacked values.
///
/// The result is exact except for a possible sticky residue from aligning
/// the far-smaller operand; the residue is strictly below the result's
/// LSB, which is all `round_to_format` needs for correct rounding in any
/// mode. The `mode` parameter only decides the sign of an exact-zero
/// cancellation result.
#[inline(always)]
pub fn add_exact(x: Exact, y: Exact, mode: RoundMode) -> Exact {
    debug_assert!(!x.sticky && !y.sticky, "inputs to add_exact must be exact");
    if x.sig == 0 {
        if y.sig == 0 {
            // ±0 + ±0: equal signs keep the sign, else mode-dependent.
            let sign = if x.sign == y.sign { x.sign } else { mode.cancellation_zero_sign() };
            return Exact { sign, exp: 0, sig: 0, sticky: false };
        }
        return y;
    }
    if y.sig == 0 {
        return x;
    }

    // Identify the operand with strictly larger magnitude (ties broken
    // after an exact aligned compare).
    let (big, small) = match cmp_magnitude(&x, &y) {
        std::cmp::Ordering::Greater => (x, y),
        std::cmp::Ordering::Less => (y, x),
        std::cmp::Ordering::Equal => {
            if x.sign != y.sign {
                // Exact cancellation.
                return Exact {
                    sign: mode.cancellation_zero_sign(),
                    exp: 0,
                    sig: 0,
                    sticky: false,
                };
            }
            (x, y)
        }
    };

    // Normalize `big` to the top of u128, leaving one bit of carry
    // headroom: MSB at bit 126.
    let lsh = 126 - (bitlen128(big.sig) - 1);
    let big_sig = big.sig << lsh;
    let big_exp = big.exp - lsh as i32;

    // Align `small` to big_exp.
    let d = big_exp - small.exp;
    let (small_sig, _round, sticky) = if d >= 0 {
        let (kept, r, s) = super::rounding::shift_right_rs(small.sig, d, false);
        // Fold the round bit back into sticky semantics by keeping it in
        // the kept value when possible: we instead keep one extra guard by
        // construction (big has headroom), so treat r as part of sticky.
        (kept, false, r || s)
    } else {
        // small's LSB sits above big_exp; shift left exactly (cannot
        // overflow: small's aligned length ≤ big's npos - big_exp = 127).
        (small.sig << (-d) as u32, false, false)
    };

    if big.sign == small.sign {
        Exact { sign: big.sign, exp: big_exp, sig: big_sig + small_sig, sticky }
    } else {
        // |big| > |small| strictly. If sticky, the true small is slightly
        // larger than small_sig: represent big - small as
        // (big_sig - small_sig - 1) + (1 - ε'), keeping sticky set.
        let sig = if sticky { big_sig - small_sig - 1 } else { big_sig - small_sig };
        Exact { sign: big.sign, exp: big_exp, sig, sticky }
    }
}

/// Compare |x| vs |y| exactly.
#[inline(always)]
fn cmp_magnitude(x: &Exact, y: &Exact) -> std::cmp::Ordering {
    let (nx, ny) = (x.npos(), y.npos());
    if nx != ny {
        return nx.cmp(&ny);
    }
    // Same MSB position: align both to the smaller exponent and compare.
    // Aligned lengths equal npos - min_exp = bitlen of the operand that
    // already sits at min_exp ≤ 128, so no overflow.
    let e = x.exp.min(y.exp);
    let xs = x.sig << (x.exp - e) as u32;
    let ys = y.sig << (y.exp - e) as u32;
    xs.cmp(&ys)
}

/// Round an exact value into `fmt` under `mode`.
#[inline(always)]
pub fn round(fmt: Format, mode: RoundMode, v: Exact) -> Rounded {
    if v.sig == 0 && !v.sticky {
        return Rounded { bits: fmt.zero(v.sign), flags: Flags::default() };
    }
    round_to_format(fmt, mode, v.sign, v.exp, v.sig, v.sticky)
}

/// Exact conversion of `bits` in `fmt` to the host's `f64`.
///
/// Exact for every supported format: each has `sig_bits ≤ 53` and an
/// exponent range inside binary64's, so every finite value (subnormals
/// included) is representable — the small formats' host differential
/// engine leans on this. NaN payloads collapse to the host qNaN (host
/// engines compare NaNs by class only).
pub fn to_f64(fmt: Format, bits: u64) -> f64 {
    if fmt == Format::DP {
        return f64::from_bits(bits);
    }
    if fmt == Format::SP {
        return f32::from_bits(bits as u32) as f64;
    }
    let d = decode(fmt, bits);
    match d.class {
        Class::Nan => f64::NAN,
        Class::Infinity => {
            if d.sign {
                f64::NEG_INFINITY
            } else {
                f64::INFINITY
            }
        }
        Class::Zero => {
            if d.sign {
                -0.0
            } else {
                0.0
            }
        }
        _ => {
            let v = (d.sig as f64) * 2f64.powi(d.exp);
            if d.sign {
                -v
            } else {
                v
            }
        }
    }
}

/// Convert a host `f64` into `fmt` under round-to-nearest-even.
///
/// This is a genuine (second) rounding: combined with an f64
/// computation it is still correctly rounded for the small formats by
/// Figueroa's innocuous-double-rounding theorem (`53 ≥ 2·sig_bits + 2`
/// holds for FP16/BF16/FP8, so `round_fmt(round_f64(x)) ==
/// round_fmt(x)` for sums and products of `fmt` values). Overflow goes
/// to ±Inf and underflow to subnormals/zero exactly as the spec
/// rounder does.
pub fn from_f64(fmt: Format, v: f64) -> u64 {
    if fmt == Format::DP {
        return v.to_bits();
    }
    let d = decode(Format::DP, v.to_bits());
    match d.class {
        Class::Nan => fmt.qnan(),
        Class::Infinity => fmt.inf(d.sign),
        Class::Zero => fmt.zero(d.sign),
        _ => round(fmt, RoundMode::NearestEven, Exact::from_decoded(&d)).bits,
    }
}

/// Invalid-operation result: canonical qNaN with the invalid flag.
fn invalid(fmt: Format) -> Rounded {
    Rounded { bits: fmt.qnan(), flags: Flags { invalid: true, ..Flags::default() } }
}

/// Quiet-NaN result without the invalid flag (NaN propagation).
fn qnan(fmt: Format) -> Rounded {
    Rounded { bits: fmt.qnan(), flags: Flags::default() }
}

/// IEEE-754 fused multiply-add: `round(a·b + c)` with a single rounding.
///
/// Special-case semantics follow IEEE 754-2019 §7.2: any NaN operand
/// propagates; `(±Inf)·(±0)` is invalid even when `c` is NaN per the
/// standard's option exercised by x86/ARM (we return qNaN either way, so
/// datapath comparisons are unaffected).
pub fn fma(fmt: Format, mode: RoundMode, a_bits: u64, b_bits: u64, c_bits: u64) -> Rounded {
    let a = decode(fmt, a_bits);
    let b = decode(fmt, b_bits);
    let c = decode(fmt, c_bits);

    // NaN propagation / invalid detection.
    let prod_invalid = (a.class == Class::Infinity && b.is_zero())
        || (b.class == Class::Infinity && a.is_zero());
    if a.class == Class::Nan || b.class == Class::Nan || c.class == Class::Nan {
        if prod_invalid {
            return invalid(fmt);
        }
        return qnan(fmt);
    }
    if prod_invalid {
        return invalid(fmt);
    }

    let psign = a.sign ^ b.sign;
    let pinf = a.class == Class::Infinity || b.class == Class::Infinity;
    match (pinf, c.class == Class::Infinity) {
        (true, true) => {
            if psign != c.sign {
                return invalid(fmt); // Inf - Inf
            }
            return Rounded { bits: fmt.inf(psign), flags: Flags::default() };
        }
        (true, false) => return Rounded { bits: fmt.inf(psign), flags: Flags::default() },
        (false, true) => return Rounded { bits: fmt.inf(c.sign), flags: Flags::default() },
        (false, false) => {}
    }

    // Finite path.
    let p = mul_exact(&a, &b);
    if p.sig == 0 && c.is_zero() {
        // ±0 + ±0 sign rules.
        let sign = if p.sign == c.sign { p.sign } else { mode.cancellation_zero_sign() };
        return Rounded { bits: fmt.zero(sign), flags: Flags::default() };
    }
    let sum = add_exact(p, Exact::from_decoded(&c), mode);
    round(fmt, mode, sum)
}

/// IEEE-754 multiply: `round(a·b)`.
pub fn mul(fmt: Format, mode: RoundMode, a_bits: u64, b_bits: u64) -> Rounded {
    let a = decode(fmt, a_bits);
    let b = decode(fmt, b_bits);
    if a.class == Class::Nan || b.class == Class::Nan {
        return qnan(fmt);
    }
    if (a.class == Class::Infinity && b.is_zero()) || (b.class == Class::Infinity && a.is_zero())
    {
        return invalid(fmt);
    }
    let sign = a.sign ^ b.sign;
    if a.class == Class::Infinity || b.class == Class::Infinity {
        return Rounded { bits: fmt.inf(sign), flags: Flags::default() };
    }
    if a.is_zero() || b.is_zero() {
        return Rounded { bits: fmt.zero(sign), flags: Flags::default() };
    }
    round(fmt, mode, mul_exact(&a, &b))
}

/// IEEE-754 add: `round(a + c)`.
pub fn add(fmt: Format, mode: RoundMode, a_bits: u64, c_bits: u64) -> Rounded {
    let a = decode(fmt, a_bits);
    let c = decode(fmt, c_bits);
    if a.class == Class::Nan || c.class == Class::Nan {
        return qnan(fmt);
    }
    match (a.class == Class::Infinity, c.class == Class::Infinity) {
        (true, true) => {
            if a.sign != c.sign {
                return invalid(fmt);
            }
            return Rounded { bits: fmt.inf(a.sign), flags: Flags::default() };
        }
        (true, false) => return Rounded { bits: fmt.inf(a.sign), flags: Flags::default() },
        (false, true) => return Rounded { bits: fmt.inf(c.sign), flags: Flags::default() },
        (false, false) => {}
    }
    let sum = add_exact(Exact::from_decoded(&a), Exact::from_decoded(&c), mode);
    round(fmt, mode, sum)
}

/// Round an exact value to `fmt` under round-to-nearest-even, producing
/// **bits only** — no exception flags. This is the rounder the lane
/// kernels ([`lanes`]) end in: the flag bookkeeping of
/// [`round_to_format`] is the only thing removed, the dataflow is a
/// line-for-line specialization (RNE never saturates on overflow, and a
/// sticky-only residue rounds to zero). Bit-identity with the generic
/// path is debug-asserted at every lane-kernel call site and re-verified
/// at run time by the engine's sampled gate-level cross-checks.
#[inline(always)]
fn round_rne_bits(fmt: Format, v: Exact) -> u64 {
    if v.sig == 0 {
        // Exact zero, or a sticky-only residue below the smallest
        // subnormal: RNE never rounds a bare sticky up.
        return fmt.zero(v.sign);
    }
    let npos = v.exp + bitlen128(v.sig) as i32;
    let target_q = (npos - fmt.sig_bits as i32).max(fmt.qmin());
    let (kept, round_bit, sticky_low) = if target_q >= v.exp {
        super::rounding::shift_right_rs(v.sig, target_q - v.exp, v.sticky)
    } else {
        (v.sig << (v.exp - target_q) as u32, false, v.sticky)
    };
    let lsb = kept & 1 == 1;
    let mut result_sig = kept as u64;
    let mut q = target_q;
    if round_bit && (sticky_low || lsb) {
        result_sig += 1;
        if result_sig == (1u64 << fmt.sig_bits) {
            result_sig >>= 1;
            q += 1;
        }
    }
    if result_sig == 0 {
        return fmt.zero(v.sign);
    }
    let msb = q + super::fp::bitlen64(result_sig) as i32 - 1;
    if msb > fmt.emax() {
        return fmt.inf(v.sign); // RNE overflows to ±Inf, never max-finite
    }
    let s = if v.sign { fmt.sign_bit() } else { 0 };
    if result_sig & fmt.hidden_bit() == 0 {
        // Subnormal: the quantum is pinned at qmin by the target_q clamp.
        debug_assert_eq!(q, fmt.qmin());
        return s | result_sig;
    }
    let biased = (q + fmt.bias() + fmt.sig_bits as i32 - 1) as u64;
    s | (biased << (fmt.sig_bits - 1)) | (result_sig & fmt.frac_mask())
}

/// RNE-rounded bits of an exact sum `x + y` (both inputs exact). Shared
/// tail of the FMA and CMA lane kernels.
#[inline(always)]
fn exact_sum_rne_bits(fmt: Format, x: Exact, y: Exact) -> u64 {
    round_rne_bits(fmt, add_exact(x, y, RoundMode::NearestEven))
}

/// Lane-batched word-level kernels: the scalar pipeline above
/// (decode → `mul_exact` → `add_exact` → round) restructured into
/// branch-light stages over fixed-width lane blocks, structure-of-arrays
/// style — the software analogue of FPnew's multi-format SIMD lanes.
///
/// Layout per block of [`LANES`] operations:
///
/// * **decode** fills separate sign/exponent/significand arrays (no
///   `Class` enum, no per-operand branches — the normal/subnormal split
///   is a mask-select), while collecting a bitmask of lanes holding
///   Inf/NaN operands;
/// * **multiply** is a pure SoA stage (`u128` products never overflow);
/// * **add + round** runs per lane through the RNE-specialized, flag-free
///   tail ([`round_rne_bits`]), which shares `add_exact` and
///   `shift_right_rs` with the generic spec;
/// * lanes flagged special are **peeled** to the scalar spec ([`fma`],
///   [`mul`], [`add`]), so NaN propagation and Inf arithmetic never leak
///   into the fast path.
///
/// The decode and multiply stages exist in two interchangeable forms:
/// a scalar SoA loop (always compiled — it is the differential-fuzzing
/// reference, exported as [`scalar_ref`]) and, behind the `simd` cargo
/// feature, `std::simd` portable-vector versions that run the same
/// dataflow over `u64x8`/`i32x8` registers. The peel rules are identical
/// in both: the u128 wide paths (DP partial products, `add_exact`, the
/// rounder) and all special lanes stay on the scalar spec.
///
/// Every lane result is debug-asserted against the scalar spec, so any
/// divergence fails loudly under `cargo test` (with or without `simd`);
/// release builds are guarded by the engine's sampled gate-level
/// cross-checks and the differential fuzzer ([`crate::arch::fuzz`]).
pub mod lanes {
    use super::*;

    /// Operations per lane block. Eight lanes keep the SoA arrays inside
    /// two cache lines for SP while exactly filling one `u64x8` vector
    /// register per column under the `simd` feature (scalar builds rely
    /// on the compiler auto-vectorizing the same loops).
    pub const LANES: usize = 8;

    /// SoA view of one decoded operand column.
    struct DecodedLanes {
        sign: [bool; LANES],
        exp: [i32; LANES],
        sig: [u64; LANES],
    }

    impl DecodedLanes {
        fn zeroed() -> DecodedLanes {
            DecodedLanes { sign: [false; LANES], exp: [0; LANES], sig: [0; LANES] }
        }
    }

    /// Branch-light SoA decode of one operand column (scalar stage;
    /// always compiled). Returns the lane bitmask of non-finite (Inf/NaN)
    /// operands — those lanes hold unusable sign/exp/sig values and must
    /// be peeled by the caller.
    #[inline(always)]
    fn decode_lanes_scalar(fmt: Format, bits: &[u64; LANES], out: &mut DecodedLanes) -> u32 {
        let ebias = fmt.bias() + fmt.sig_bits as i32 - 1;
        let mut special = 0u32;
        for i in 0..LANES {
            let w = bits[i] & fmt.storage_mask();
            let biased = (w >> (fmt.sig_bits - 1)) & fmt.emax_biased();
            let frac = w & fmt.frac_mask();
            // Normal lanes get the hidden bit OR-ed in; subnormal/zero
            // lanes keep the raw fraction at the qmin exponent. Both are
            // mask selects, not branches.
            let is_norm = (biased != 0) as u64;
            special |= ((biased == fmt.emax_biased()) as u32) << i;
            out.sign[i] = w & fmt.sign_bit() != 0;
            out.sig[i] = frac | (is_norm << (fmt.sig_bits - 1));
            out.exp[i] = biased.max(1) as i32 - ebias;
        }
        special
    }

    /// Multiply stage (scalar form): sign XOR, exponent add, exact
    /// significand product widened to u128 (53+53 bits max).
    #[inline(always)]
    fn mul_stage_scalar(
        da: &DecodedLanes,
        db: &DecodedLanes,
        psign: &mut [bool; LANES],
        pexp: &mut [i32; LANES],
        psig: &mut [u128; LANES],
    ) {
        for i in 0..LANES {
            psign[i] = da.sign[i] ^ db.sign[i];
            pexp[i] = da.exp[i] + db.exp[i];
            psig[i] = da.sig[i] as u128 * db.sig[i] as u128;
        }
    }

    /// `std::simd` portable-vector stages (nightly `portable_simd`,
    /// gated by the `simd` cargo feature). Same dataflow as the scalar
    /// stages, one `u64x8` register per operand column.
    #[cfg(feature = "simd")]
    mod vector {
        use super::{DecodedLanes, Format, LANES};
        use std::simd::prelude::*;

        /// Vector decode: masked field extraction, hidden-bit OR via
        /// mask-select, specials bitmask via a lane compare against the
        /// all-ones exponent.
        #[inline(always)]
        pub(super) fn decode_lanes(
            fmt: Format,
            bits: &[u64; LANES],
            out: &mut DecodedLanes,
        ) -> u32 {
            let ebias = fmt.bias() + fmt.sig_bits as i32 - 1;
            let w = Simd::<u64, LANES>::from_array(*bits) & Simd::splat(fmt.storage_mask());
            let biased = (w >> Simd::splat(fmt.sig_bits as u64 - 1)) & Simd::splat(fmt.emax_biased());
            let is_norm = biased.simd_ne(Simd::splat(0));
            let hidden = is_norm.select(Simd::splat(fmt.hidden_bit()), Simd::splat(0));
            let special = biased.simd_eq(Simd::splat(fmt.emax_biased())).to_bitmask() as u32;
            out.sign = (w & Simd::splat(fmt.sign_bit())).simd_ne(Simd::splat(0)).to_array();
            out.sig = ((w & Simd::splat(fmt.frac_mask())) | hidden).to_array();
            out.exp = (biased.cast::<i32>().simd_max(Simd::splat(1)) - Simd::splat(ebias))
                .to_array();
            special
        }

        /// Vector multiply stage. SP partial products (24+24 = 48 bits)
        /// fit `u64x8` lanes; the DP 106-bit product is the documented
        /// u128 peel and stays a scalar loop.
        #[inline(always)]
        pub(super) fn mul_stage(
            fmt: Format,
            da: &DecodedLanes,
            db: &DecodedLanes,
            psign: &mut [bool; LANES],
            pexp: &mut [i32; LANES],
            psig: &mut [u128; LANES],
        ) {
            *psign =
                (Mask::<i64, LANES>::from_array(da.sign) ^ Mask::from_array(db.sign)).to_array();
            *pexp = (Simd::<i32, LANES>::from_array(da.exp) + Simd::from_array(db.exp)).to_array();
            if 2 * fmt.sig_bits <= 64 {
                let p = Simd::<u64, LANES>::from_array(da.sig) * Simd::from_array(db.sig);
                let pa = p.to_array();
                for i in 0..LANES {
                    psig[i] = pa[i] as u128;
                }
            } else {
                for i in 0..LANES {
                    psig[i] = da.sig[i] as u128 * db.sig[i] as u128;
                }
            }
        }
    }

    /// Dispatching decode stage: vector when the `simd` feature is on,
    /// scalar SoA otherwise.
    #[inline(always)]
    fn decode_lanes(fmt: Format, bits: &[u64; LANES], out: &mut DecodedLanes) -> u32 {
        #[cfg(feature = "simd")]
        {
            vector::decode_lanes(fmt, bits, out)
        }
        #[cfg(not(feature = "simd"))]
        {
            decode_lanes_scalar(fmt, bits, out)
        }
    }

    /// Dispatching multiply stage (see [`decode_lanes`]).
    #[inline(always)]
    fn mul_stage(
        fmt: Format,
        da: &DecodedLanes,
        db: &DecodedLanes,
        psign: &mut [bool; LANES],
        pexp: &mut [i32; LANES],
        psig: &mut [u128; LANES],
    ) {
        #[cfg(feature = "simd")]
        {
            vector::mul_stage(fmt, da, db, psign, pexp, psig)
        }
        #[cfg(not(feature = "simd"))]
        {
            let _ = fmt;
            mul_stage_scalar(da, db, psign, pexp, psig)
        }
    }

    /// Fused add + round tail: per lane, special lanes take the scalar
    /// [`fma`] spec; the rest run the exact-sum RNE rounder. Shared by
    /// the dispatching and scalar-reference block entries.
    #[inline(always)]
    #[allow(clippy::too_many_arguments)]
    fn fma_tail(
        fmt: Format,
        a: &[u64; LANES],
        b: &[u64; LANES],
        c: &[u64; LANES],
        dc: &DecodedLanes,
        special: u32,
        psign: &[bool; LANES],
        pexp: &[i32; LANES],
        psig: &[u128; LANES],
        out: &mut [u64; LANES],
    ) {
        for i in 0..LANES {
            out[i] = if special & (1 << i) != 0 {
                fma(fmt, RoundMode::NearestEven, a[i], b[i], c[i]).bits
            } else {
                exact_sum_rne_bits(
                    fmt,
                    Exact { sign: psign[i], exp: pexp[i], sig: psig[i], sticky: false },
                    Exact {
                        sign: dc.sign[i],
                        exp: dc.exp[i],
                        sig: dc.sig[i] as u128,
                        sticky: false,
                    },
                )
            };
            debug_assert_eq!(
                out[i],
                fma(fmt, RoundMode::NearestEven, a[i], b[i], c[i]).bits,
                "lane {i} diverged from the scalar fused spec: a={:#x} b={:#x} c={:#x}",
                a[i],
                b[i],
                c[i]
            );
        }
    }

    /// Cascade add + round tail: round the product, then (unless the
    /// rounded product overflowed to Inf — scalar peel) the second RNE
    /// rounding of `p + c`.
    #[inline(always)]
    #[allow(clippy::too_many_arguments)]
    fn cma_tail(
        fmt: Format,
        a: &[u64; LANES],
        b: &[u64; LANES],
        c: &[u64; LANES],
        dc: &DecodedLanes,
        special: u32,
        psign: &[bool; LANES],
        pexp: &[i32; LANES],
        psig: &[u128; LANES],
        out: &mut [u64; LANES],
    ) {
        for i in 0..LANES {
            out[i] = if special & (1 << i) != 0 {
                let p = mul(fmt, RoundMode::NearestEven, a[i], b[i]);
                add(fmt, RoundMode::NearestEven, p.bits, c[i]).bits
            } else {
                let pbits = round_rne_bits(
                    fmt,
                    Exact { sign: psign[i], exp: pexp[i], sig: psig[i], sticky: false },
                );
                let dp = decode(fmt, pbits);
                if dp.class == Class::Infinity {
                    // Rounded product overflowed: the second rounding must
                    // run Inf arithmetic — scalar spec.
                    add(fmt, RoundMode::NearestEven, pbits, c[i]).bits
                } else {
                    exact_sum_rne_bits(
                        fmt,
                        Exact { sign: dp.sign, exp: dp.exp, sig: dp.sig as u128, sticky: false },
                        Exact {
                            sign: dc.sign[i],
                            exp: dc.exp[i],
                            sig: dc.sig[i] as u128,
                            sticky: false,
                        },
                    )
                }
            };
            debug_assert_eq!(
                out[i],
                {
                    let p = mul(fmt, RoundMode::NearestEven, a[i], b[i]);
                    add(fmt, RoundMode::NearestEven, p.bits, c[i]).bits
                },
                "lane {i} diverged from the scalar cascade spec: a={:#x} b={:#x} c={:#x}",
                a[i],
                b[i],
                c[i]
            );
        }
    }

    /// Multiply round tail: one RNE rounding of the exact product.
    #[inline(always)]
    fn mul_tail(
        fmt: Format,
        a: &[u64; LANES],
        b: &[u64; LANES],
        special: u32,
        psign: &[bool; LANES],
        pexp: &[i32; LANES],
        psig: &[u128; LANES],
        out: &mut [u64; LANES],
    ) {
        for i in 0..LANES {
            out[i] = if special & (1 << i) != 0 {
                mul(fmt, RoundMode::NearestEven, a[i], b[i]).bits
            } else {
                round_rne_bits(
                    fmt,
                    Exact { sign: psign[i], exp: pexp[i], sig: psig[i], sticky: false },
                )
            };
            debug_assert_eq!(out[i], mul(fmt, RoundMode::NearestEven, a[i], b[i]).bits);
        }
    }

    /// Add tail: one RNE rounding of the exact sum of two decoded
    /// columns (no product stage).
    #[inline(always)]
    fn add_tail(
        fmt: Format,
        a: &[u64; LANES],
        c: &[u64; LANES],
        da: &DecodedLanes,
        dc: &DecodedLanes,
        special: u32,
        out: &mut [u64; LANES],
    ) {
        for i in 0..LANES {
            out[i] = if special & (1 << i) != 0 {
                add(fmt, RoundMode::NearestEven, a[i], c[i]).bits
            } else {
                exact_sum_rne_bits(
                    fmt,
                    Exact { sign: da.sign[i], exp: da.exp[i], sig: da.sig[i] as u128, sticky: false },
                    Exact { sign: dc.sign[i], exp: dc.exp[i], sig: dc.sig[i] as u128, sticky: false },
                )
            };
            debug_assert_eq!(out[i], add(fmt, RoundMode::NearestEven, a[i], c[i]).bits);
        }
    }

    /// One lane block of fused FMAs (`round(a·b + c)`, RNE). Lanes with
    /// any Inf/NaN operand peel to the scalar [`fma`] spec.
    pub fn fma_block_rne(
        fmt: Format,
        a: &[u64; LANES],
        b: &[u64; LANES],
        c: &[u64; LANES],
        out: &mut [u64; LANES],
    ) {
        let mut da = DecodedLanes::zeroed();
        let mut db = DecodedLanes::zeroed();
        let mut dc = DecodedLanes::zeroed();
        let mut special = decode_lanes(fmt, a, &mut da);
        special |= decode_lanes(fmt, b, &mut db);
        special |= decode_lanes(fmt, c, &mut dc);
        let mut psign = [false; LANES];
        let mut pexp = [0i32; LANES];
        let mut psig = [0u128; LANES];
        mul_stage(fmt, &da, &db, &mut psign, &mut pexp, &mut psig);
        fma_tail(fmt, a, b, c, &dc, special, &psign, &pexp, &psig, out);
    }

    /// One lane block of cascade FMACs: `round(a·b)` then
    /// `round(p + c)`, both RNE — the CMA units' two-rounding Table-I
    /// semantics. Lanes with Inf/NaN operands, or whose rounded product
    /// overflows to Inf, peel to the scalar [`mul`]+[`add`] composition.
    pub fn cma_block_rne(
        fmt: Format,
        a: &[u64; LANES],
        b: &[u64; LANES],
        c: &[u64; LANES],
        out: &mut [u64; LANES],
    ) {
        let mut da = DecodedLanes::zeroed();
        let mut db = DecodedLanes::zeroed();
        let mut dc = DecodedLanes::zeroed();
        let mut special = decode_lanes(fmt, a, &mut da);
        special |= decode_lanes(fmt, b, &mut db);
        special |= decode_lanes(fmt, c, &mut dc);
        let mut psign = [false; LANES];
        let mut pexp = [0i32; LANES];
        let mut psig = [0u128; LANES];
        mul_stage(fmt, &da, &db, &mut psign, &mut pexp, &mut psig);
        cma_tail(fmt, a, b, c, &dc, special, &psign, &pexp, &psig, out);
    }

    /// One lane block of multiplies (`round(a·b)`, RNE) — the chip
    /// sequencer's `Mul` burst path.
    pub fn mul_block_rne(fmt: Format, a: &[u64; LANES], b: &[u64; LANES], out: &mut [u64; LANES]) {
        let mut da = DecodedLanes::zeroed();
        let mut db = DecodedLanes::zeroed();
        let mut special = decode_lanes(fmt, a, &mut da);
        special |= decode_lanes(fmt, b, &mut db);
        let mut psign = [false; LANES];
        let mut pexp = [0i32; LANES];
        let mut psig = [0u128; LANES];
        mul_stage(fmt, &da, &db, &mut psign, &mut pexp, &mut psig);
        mul_tail(fmt, a, b, special, &psign, &pexp, &psig, out);
    }

    /// One lane block of adds (`round(a + c)`, RNE) — the chip
    /// sequencer's `Add` burst path.
    pub fn add_block_rne(fmt: Format, a: &[u64; LANES], c: &[u64; LANES], out: &mut [u64; LANES]) {
        let mut da = DecodedLanes::zeroed();
        let mut dc = DecodedLanes::zeroed();
        let mut special = decode_lanes(fmt, a, &mut da);
        special |= decode_lanes(fmt, c, &mut dc);
        add_tail(fmt, a, c, &da, &dc, special, out);
    }

    /// Scalar-stage lane blocks, always compiled regardless of the
    /// `simd` feature: the SoA loops the vector stages are diffed
    /// against. Under `--features simd` these are a *distinct* code path
    /// from the dispatching blocks above (which run the `std::simd`
    /// stages); without the feature the two are identical. The
    /// differential fuzzer and the `scalar_lane` bench rows call these.
    pub mod scalar_ref {
        use super::*;

        /// Scalar-stage FMA block (see [`super::fma_block_rne`]).
        pub fn fma_block_rne(
            fmt: Format,
            a: &[u64; LANES],
            b: &[u64; LANES],
            c: &[u64; LANES],
            out: &mut [u64; LANES],
        ) {
            let mut da = DecodedLanes::zeroed();
            let mut db = DecodedLanes::zeroed();
            let mut dc = DecodedLanes::zeroed();
            let mut special = decode_lanes_scalar(fmt, a, &mut da);
            special |= decode_lanes_scalar(fmt, b, &mut db);
            special |= decode_lanes_scalar(fmt, c, &mut dc);
            let mut psign = [false; LANES];
            let mut pexp = [0i32; LANES];
            let mut psig = [0u128; LANES];
            mul_stage_scalar(&da, &db, &mut psign, &mut pexp, &mut psig);
            fma_tail(fmt, a, b, c, &dc, special, &psign, &pexp, &psig, out);
        }

        /// Scalar-stage CMA block (see [`super::cma_block_rne`]).
        pub fn cma_block_rne(
            fmt: Format,
            a: &[u64; LANES],
            b: &[u64; LANES],
            c: &[u64; LANES],
            out: &mut [u64; LANES],
        ) {
            let mut da = DecodedLanes::zeroed();
            let mut db = DecodedLanes::zeroed();
            let mut dc = DecodedLanes::zeroed();
            let mut special = decode_lanes_scalar(fmt, a, &mut da);
            special |= decode_lanes_scalar(fmt, b, &mut db);
            special |= decode_lanes_scalar(fmt, c, &mut dc);
            let mut psign = [false; LANES];
            let mut pexp = [0i32; LANES];
            let mut psig = [0u128; LANES];
            mul_stage_scalar(&da, &db, &mut psign, &mut pexp, &mut psig);
            cma_tail(fmt, a, b, c, &dc, special, &psign, &pexp, &psig, out);
        }

        /// Scalar-stage Mul block (see [`super::mul_block_rne`]).
        pub fn mul_block_rne(
            fmt: Format,
            a: &[u64; LANES],
            b: &[u64; LANES],
            out: &mut [u64; LANES],
        ) {
            let mut da = DecodedLanes::zeroed();
            let mut db = DecodedLanes::zeroed();
            let mut special = decode_lanes_scalar(fmt, a, &mut da);
            special |= decode_lanes_scalar(fmt, b, &mut db);
            let mut psign = [false; LANES];
            let mut pexp = [0i32; LANES];
            let mut psig = [0u128; LANES];
            mul_stage_scalar(&da, &db, &mut psign, &mut pexp, &mut psig);
            mul_tail(fmt, a, b, special, &psign, &pexp, &psig, out);
        }

        /// Scalar-stage Add block (see [`super::add_block_rne`]).
        pub fn add_block_rne(
            fmt: Format,
            a: &[u64; LANES],
            c: &[u64; LANES],
            out: &mut [u64; LANES],
        ) {
            let mut da = DecodedLanes::zeroed();
            let mut dc = DecodedLanes::zeroed();
            let mut special = decode_lanes_scalar(fmt, a, &mut da);
            special |= decode_lanes_scalar(fmt, c, &mut dc);
            add_tail(fmt, a, c, &da, &dc, special, out);
        }
    }

    /// SIMD-within-a-register packed ops, FPnew style: small-format
    /// elements packed little-endian into 32-bit words (2×FP16/BF16 or
    /// 4×FP8 per word), executed by widening each word group into a
    /// full SoA lane block and re-packing the results. A lane block
    /// holds `LANES` elements regardless of format, so one block pass
    /// covers 4 words of FP16/BF16 or 2 words of FP8 — the packing
    /// multiplies *memory* density per word exactly as FPnew's packed
    /// lanes do, while the compute stages stay the (already
    /// format-generic, simd-dispatching) lane kernels. Specials peel
    /// per element through the same lane-block rules; trailing partial
    /// word groups pad with +0 lanes, which are inert and never
    /// written back.
    pub mod packed {
        use super::*;

        /// Packed elements per 32-bit word (2 for the 16-bit formats,
        /// 4 for FP8).
        pub fn elems_per_word(fmt: Format) -> usize {
            (32 / fmt.width()) as usize
        }

        /// True for formats narrow enough to pack (width ≤ 16).
        pub fn supports(fmt: Format) -> bool {
            fmt.width() <= 16
        }

        /// Pack `elems_per_word` raw element bit patterns into one
        /// word, element 0 in the low bits.
        pub fn pack_word(fmt: Format, elems: &[u64]) -> u32 {
            debug_assert_eq!(elems.len(), elems_per_word(fmt));
            let mut word = 0u32;
            for (i, &e) in elems.iter().enumerate() {
                word |= ((e & fmt.storage_mask()) as u32) << (i as u32 * fmt.width());
            }
            word
        }

        /// Unpack one word into `elems_per_word` raw element patterns.
        pub fn unpack_word(fmt: Format, word: u32, out: &mut [u64]) {
            debug_assert_eq!(out.len(), elems_per_word(fmt));
            for (i, o) in out.iter_mut().enumerate() {
                *o = ((word >> (i as u32 * fmt.width())) as u64) & fmt.storage_mask();
            }
        }

        /// Shared word-group driver: unpack up to `LANES` elements'
        /// worth of words per column, run one lane block, re-pack.
        #[inline(always)]
        fn drive(
            fmt: Format,
            cols: [&[u32]; 3],
            out: &mut [u32],
            block: impl Fn(&[u64; LANES], &[u64; LANES], &[u64; LANES], &mut [u64; LANES]),
        ) {
            assert!(supports(fmt), "packed ops need width <= 16, got {}", fmt.width());
            for col in cols {
                assert_eq!(col.len(), out.len(), "packed column length mismatch");
            }
            let epw = elems_per_word(fmt);
            let wpb = LANES / epw;
            let mut i = 0;
            while i < out.len() {
                let n = wpb.min(out.len() - i);
                let mut la = [0u64; LANES];
                let mut lb = [0u64; LANES];
                let mut lc = [0u64; LANES];
                let mut lo = [0u64; LANES];
                for j in 0..n {
                    unpack_word(fmt, cols[0][i + j], &mut la[j * epw..(j + 1) * epw]);
                    unpack_word(fmt, cols[1][i + j], &mut lb[j * epw..(j + 1) * epw]);
                    unpack_word(fmt, cols[2][i + j], &mut lc[j * epw..(j + 1) * epw]);
                }
                block(&la, &lb, &lc, &mut lo);
                for j in 0..n {
                    out[i + j] = pack_word(fmt, &lo[j * epw..(j + 1) * epw]);
                }
                i += n;
            }
        }

        /// Packed fused FMA over word slices: every element computes
        /// `round(a·b + c)` (RNE).
        pub fn fma_words(fmt: Format, a: &[u32], b: &[u32], c: &[u32], out: &mut [u32]) {
            drive(fmt, [a, b, c], out, |la, lb, lc, lo| fma_block_rne(fmt, la, lb, lc, lo));
        }

        /// Packed cascade FMAC over word slices (two roundings).
        pub fn cma_words(fmt: Format, a: &[u32], b: &[u32], c: &[u32], out: &mut [u32]) {
            drive(fmt, [a, b, c], out, |la, lb, lc, lo| cma_block_rne(fmt, la, lb, lc, lo));
        }

        /// Packed multiply over word slices.
        pub fn mul_words(fmt: Format, a: &[u32], b: &[u32], out: &mut [u32]) {
            drive(fmt, [a, b, b], out, |la, lb, _, lo| mul_block_rne(fmt, la, lb, lo));
        }

        /// Packed add over word slices.
        pub fn add_words(fmt: Format, a: &[u32], c: &[u32], out: &mut [u32]) {
            drive(fmt, [a, c, c], out, |la, _, lc, lo| add_block_rne(fmt, la, lc, lo));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fma32(a: f32, b: f32, c: f32) -> f32 {
        f32::from_bits(
            fma(
                Format::SP,
                RoundMode::NearestEven,
                a.to_bits() as u64,
                b.to_bits() as u64,
                c.to_bits() as u64,
            )
            .bits as u32,
        )
    }

    fn fma64(a: f64, b: f64, c: f64) -> f64 {
        f64::from_bits(
            fma(Format::DP, RoundMode::NearestEven, a.to_bits(), b.to_bits(), c.to_bits()).bits,
        )
    }

    fn same32(x: f32, y: f32) -> bool {
        (x.is_nan() && y.is_nan()) || x.to_bits() == y.to_bits()
    }

    fn same64(x: f64, y: f64) -> bool {
        (x.is_nan() && y.is_nan()) || x.to_bits() == y.to_bits()
    }

    #[test]
    fn fma_simple_values() {
        assert_eq!(fma32(1.5, 2.0, 0.25), 3.25);
        assert_eq!(fma32(-1.5, 2.0, 0.25), -2.75);
        assert_eq!(fma64(1.5, 2.0, 0.25), 3.25);
        assert_eq!(fma32(0.0, 5.0, 7.0), 7.0);
    }

    #[test]
    fn fma_is_single_rounding() {
        // Classic fused-vs-cascade discriminator: a·b lands exactly between
        // two representable values and c nudges it; a two-rounding cascade
        // gets it wrong. (1 + 2^-12)^2 = 1 + 2^-11 + 2^-24.
        let a = 1.0f32 + f32::EPSILON * 2048.0; // 1 + 2^-12
        let c = -(1.0f32 + 2.0 * f32::EPSILON * 2048.0); // -(1 + 2^-11)
        let fused = fma32(a, a, c);
        assert_eq!(fused, 2f32.powi(-24));
        // Cascade result for comparison: round(a·a) = 1 + 2^-11 (the 2^-24
        // is rounded away as a tie-to-even), so cascade gives exactly 0.
        let r1 = mul(Format::SP, RoundMode::NearestEven, a.to_bits() as u64, a.to_bits() as u64);
        let r2 = add(Format::SP, RoundMode::NearestEven, r1.bits, c.to_bits() as u64);
        assert_eq!(f32::from_bits(r2.bits as u32), 0.0);
    }

    #[test]
    fn fma_matches_hardware_exhaustive_smallset() {
        // Deterministic structured operands: all sign/exponent-extreme
        // combinations of a small value set.
        let vals = [
            0.0f32,
            -0.0,
            1.0,
            -1.0,
            1.5,
            f32::MIN_POSITIVE,
            -f32::MIN_POSITIVE,
            f32::MIN_POSITIVE / 4.0, // subnormal
            f32::MAX,
            -f32::MAX,
            f32::INFINITY,
            f32::NEG_INFINITY,
            f32::NAN,
            f32::EPSILON,
            2f32.powi(-149),
            3.4028e38,
        ];
        for &a in &vals {
            for &b in &vals {
                for &c in &vals {
                    let got = fma32(a, b, c);
                    let want = a.mul_add(b, c);
                    assert!(
                        same32(got, want),
                        "fma({a:e},{b:e},{c:e}) = {got:e}, want {want:e}"
                    );
                }
            }
        }
    }

    #[test]
    fn fma_matches_hardware_dp_smallset() {
        let vals = [
            0.0f64,
            -0.0,
            1.0,
            -1.0,
            1.0 + f64::EPSILON,
            f64::MIN_POSITIVE,
            f64::MIN_POSITIVE / 8.0,
            f64::MAX,
            -f64::MAX,
            f64::INFINITY,
            f64::NEG_INFINITY,
            f64::NAN,
            2f64.powi(-1074),
            -2f64.powi(-1074),
        ];
        for &a in &vals {
            for &b in &vals {
                for &c in &vals {
                    let got = fma64(a, b, c);
                    let want = a.mul_add(b, c);
                    assert!(
                        same64(got, want),
                        "fma({a:e},{b:e},{c:e}) = {got:e}, want {want:e}"
                    );
                }
            }
        }
    }

    #[test]
    fn cancellation_zero_signs() {
        // 1·1 + (-1) = +0 under RNE, -0 under RD.
        let r = fma(Format::SP, RoundMode::NearestEven, 0x3f80_0000, 0x3f80_0000, 0xbf80_0000);
        assert_eq!(r.bits, 0);
        let r = fma(Format::SP, RoundMode::TowardNegative, 0x3f80_0000, 0x3f80_0000, 0xbf80_0000);
        assert_eq!(r.bits as u32, (-0.0f32).to_bits());
        // (+0)·1 + (+0) keeps +0; (+0)·1 + (-0) is +0 under RNE.
        let r = fma32(0.0, 1.0, 0.0);
        assert_eq!(r.to_bits(), 0);
        let r = fma32(0.0, 1.0, -0.0);
        assert_eq!(r.to_bits(), 0);
        // (-0)·1 + (-0) = -0.
        let r = fma32(-0.0, 1.0, -0.0);
        assert_eq!(r.to_bits(), (-0.0f32).to_bits());
    }

    #[test]
    fn invalid_cases() {
        let f = Format::SP;
        let inf = f32::INFINITY.to_bits() as u64;
        let zero = 0u64;
        let one = 1.0f32.to_bits() as u64;
        // Inf · 0
        assert!(fma(f, RoundMode::NearestEven, inf, zero, one).flags.invalid);
        // Inf - Inf through the addend
        let ninf = f32::NEG_INFINITY.to_bits() as u64;
        assert!(fma(f, RoundMode::NearestEven, inf, one, ninf).flags.invalid);
        // Inf · 0 + NaN is still invalid (we exercise the x86 option)
        let nan = f32::NAN.to_bits() as u64;
        assert!(fma(f, RoundMode::NearestEven, inf, zero, nan).flags.invalid);
        // Plain NaN propagation is not invalid.
        assert!(!fma(f, RoundMode::NearestEven, nan, one, one).flags.invalid);
    }

    #[test]
    fn subnormal_results() {
        // Product of two tiny normals lands in the subnormal range.
        let a = f32::MIN_POSITIVE; // 2^-126
        let b = 0.5f32;
        let got = fma32(a, b, 0.0);
        assert_eq!(got, a.mul_add(b, 0.0));
        assert_eq!(got, 2f32.powi(-127));
        // Subnormal × subnormal underflows to zero (RNE). (Constructed via
        // from_bits: powi(-140) itself underflows through its reciprocal.)
        let s = f32::from_bits(1 << 9); // 2^-140
        assert_eq!(fma32(s, s, 0.0), 0.0);
        // ... but toward-positive gives min subnormal.
        let r = fma(
            Format::SP,
            RoundMode::TowardPositive,
            s.to_bits() as u64,
            s.to_bits() as u64,
            0,
        );
        assert_eq!(r.bits, 1);
    }

    #[test]
    fn add_exact_sticky_subtraction() {
        // x = 1.0, y = -(2^-100): result must be just under 1.0 → the
        // largest float < 1.0 under RZ, and 1.0 under RNE.
        let one = 1.0f32.to_bits() as u64;
        let tiny = (2f32.powi(-100)).to_bits() as u64 | (1u64 << 31);
        let rz = add(Format::SP, RoundMode::TowardZero, one, tiny);
        assert_eq!(f32::from_bits(rz.bits as u32), 1.0 - f32::EPSILON / 2.0);
        let rn = add(Format::SP, RoundMode::NearestEven, one, tiny);
        assert_eq!(f32::from_bits(rn.bits as u32), 1.0);
        assert!(rn.flags.inexact);
    }

    #[test]
    fn mul_add_flags() {
        // Overflow flag.
        let r = mul(
            Format::SP,
            RoundMode::NearestEven,
            f32::MAX.to_bits() as u64,
            2.0f32.to_bits() as u64,
        );
        assert!(r.flags.overflow);
        assert_eq!(r.bits as u32, f32::INFINITY.to_bits());
        // Exact operations raise nothing.
        let r = mul(Format::SP, RoundMode::NearestEven, 3.0f32.to_bits() as u64, 0.5f32.to_bits() as u64);
        assert_eq!(r.flags, Flags::default());
    }

    #[test]
    fn lane_blocks_match_scalar_spec_randomized() {
        use crate::util::Rng;
        // Raw uniform bit patterns: every class (zero, subnormal, normal,
        // Inf, NaN) appears, so both the fast path and the peel are hit.
        for fmt in Format::all() {
            let mut rng = Rng::new(0x1a_e5 ^ ((fmt.exp_bits as u64) << 8) ^ fmt.sig_bits as u64);
            for _ in 0..500 {
                let mut a = [0u64; lanes::LANES];
                let mut b = [0u64; lanes::LANES];
                let mut c = [0u64; lanes::LANES];
                for i in 0..lanes::LANES {
                    a[i] = rng.next_u64() & fmt.storage_mask();
                    b[i] = rng.next_u64() & fmt.storage_mask();
                    c[i] = rng.next_u64() & fmt.storage_mask();
                }
                let mut out = [0u64; lanes::LANES];
                lanes::fma_block_rne(fmt, &a, &b, &c, &mut out);
                for i in 0..lanes::LANES {
                    let want = fma(fmt, RoundMode::NearestEven, a[i], b[i], c[i]).bits;
                    assert_eq!(out[i], want, "fma lane {i}: {:#x},{:#x},{:#x}", a[i], b[i], c[i]);
                }
                lanes::cma_block_rne(fmt, &a, &b, &c, &mut out);
                for i in 0..lanes::LANES {
                    let p = mul(fmt, RoundMode::NearestEven, a[i], b[i]);
                    let want = add(fmt, RoundMode::NearestEven, p.bits, c[i]).bits;
                    assert_eq!(out[i], want, "cma lane {i}: {:#x},{:#x},{:#x}", a[i], b[i], c[i]);
                }
                lanes::mul_block_rne(fmt, &a, &b, &mut out);
                for i in 0..lanes::LANES {
                    let want = mul(fmt, RoundMode::NearestEven, a[i], b[i]).bits;
                    assert_eq!(out[i], want, "mul lane {i}");
                }
                lanes::add_block_rne(fmt, &a, &c, &mut out);
                for i in 0..lanes::LANES {
                    let want = add(fmt, RoundMode::NearestEven, a[i], c[i]).bits;
                    assert_eq!(out[i], want, "add lane {i}");
                }
            }
        }
    }

    #[test]
    fn lane_blocks_handle_directed_special_mixes() {
        // Hand-placed specials in every lane position: Inf·0, NaN
        // propagation, overflow, subnormal products, exact cancellation.
        let fmt = Format::SP;
        let inf = f32::INFINITY.to_bits() as u64;
        let nan = f32::NAN.to_bits() as u64;
        let max = f32::MAX.to_bits() as u64;
        let sub = 1u64; // min subnormal
        let one = 1.0f32.to_bits() as u64;
        let none = (-1.0f32).to_bits() as u64;
        let a = [inf, nan, max, sub, one, 0, inf, one];
        let b = [0, one, max, sub, one, inf, inf, none];
        let c = [one, nan, max, sub, none, nan, inf, one];
        let mut out = [0u64; lanes::LANES];
        lanes::fma_block_rne(fmt, &a, &b, &c, &mut out);
        for i in 0..lanes::LANES {
            assert_eq!(out[i], fma(fmt, RoundMode::NearestEven, a[i], b[i], c[i]).bits, "lane {i}");
        }
        lanes::cma_block_rne(fmt, &a, &b, &c, &mut out);
        for i in 0..lanes::LANES {
            let p = mul(fmt, RoundMode::NearestEven, a[i], b[i]);
            assert_eq!(out[i], add(fmt, RoundMode::NearestEven, p.bits, c[i]).bits, "lane {i}");
        }
    }

    #[test]
    fn f64_conversion_roundtrips_exhaustive_small_formats() {
        // Every storage pattern of every sub-32-bit format: finite
        // values must round-trip bit-exact through f64 (the conversions
        // are exact by construction); NaNs canonicalize to the qNaN.
        for fmt in [Format::FP16, Format::BF16, Format::FP8E4M3, Format::FP8E5M2] {
            for bits in 0..=fmt.storage_mask() {
                let v = to_f64(fmt, bits);
                let back = from_f64(fmt, v);
                let d = decode(fmt, bits);
                match d.class {
                    Class::Nan => {
                        assert_eq!(back, fmt.qnan(), "{fmt} NaN {bits:#x}");
                        assert!(v.is_nan());
                    }
                    _ => {
                        assert_eq!(back, bits, "{fmt} {bits:#x} -> {v:e} -> {back:#x}");
                    }
                }
            }
        }
        // FP16/BF16 agree with f32's own narrowing on a spot set (f32 ->
        // fp16 via f64 is exact-then-round, same as direct rounding).
        assert_eq!(from_f64(Format::FP16, 1.0), 0x3c00);
        assert_eq!(from_f64(Format::FP16, 65504.0), 0x7bff); // fp16 max
        assert_eq!(from_f64(Format::FP16, 65520.0), 0x7c00); // rounds to Inf
        assert_eq!(from_f64(Format::BF16, 1.0), 0x3f80);
        assert_eq!(from_f64(Format::FP8E4M3, 1.5), 0x3c);
        assert_eq!(from_f64(Format::FP8E5M2, -2.0), 0xc0);
        assert_eq!(from_f64(Format::FP16, 1e-30), 0); // underflow to zero
    }

    #[test]
    fn packed_word_roundtrip_and_layout() {
        use super::lanes::packed;
        // FP16: 2 elements per word, element 0 in the low half.
        assert_eq!(packed::elems_per_word(Format::FP16), 2);
        assert_eq!(packed::elems_per_word(Format::BF16), 2);
        assert_eq!(packed::elems_per_word(Format::FP8E4M3), 4);
        assert_eq!(packed::elems_per_word(Format::FP8E5M2), 4);
        assert!(!packed::supports(Format::SP));
        assert!(!packed::supports(Format::DP));
        let w = packed::pack_word(Format::FP16, &[0x3c00, 0xc000]);
        assert_eq!(w, 0xc000_3c00);
        let mut out = [0u64; 2];
        packed::unpack_word(Format::FP16, w, &mut out);
        assert_eq!(out, [0x3c00, 0xc000]);
        let w = packed::pack_word(Format::FP8E4M3, &[0x01, 0x02, 0x03, 0x80]);
        assert_eq!(w, 0x8003_0201);
        let mut out = [0u64; 4];
        packed::unpack_word(Format::FP8E4M3, w, &mut out);
        assert_eq!(out, [0x01, 0x02, 0x03, 0x80]);
    }

    #[test]
    fn packed_ops_match_scalar_spec_randomized() {
        use super::lanes::packed;
        use crate::util::Rng;
        // Random words (hence random element classes — specials land at
        // their natural rates and exercise the peel), with slice lengths
        // that cover both full word groups and the padded tail.
        for fmt in [Format::FP16, Format::BF16, Format::FP8E4M3, Format::FP8E5M2] {
            let epw = packed::elems_per_word(fmt);
            let mut rng = Rng::new(0x9ac_ed ^ fmt.sig_bits as u64);
            for words in [1usize, 2, 3, 7, 16] {
                let gen_col = |rng: &mut Rng| -> Vec<u32> {
                    (0..words).map(|_| rng.next_u64() as u32).collect()
                };
                let a = gen_col(&mut rng);
                let b = gen_col(&mut rng);
                let c = gen_col(&mut rng);
                let mut out = vec![0u32; words];
                let unpack_all = |col: &[u32]| -> Vec<u64> {
                    let mut v = vec![0u64; words * epw];
                    for (i, &w) in col.iter().enumerate() {
                        packed::unpack_word(fmt, w, &mut v[i * epw..(i + 1) * epw]);
                    }
                    v
                };
                let (ea, eb, ec) = (unpack_all(&a), unpack_all(&b), unpack_all(&c));

                packed::fma_words(fmt, &a, &b, &c, &mut out);
                let eo = unpack_all(&out);
                for i in 0..words * epw {
                    let want = fma(fmt, RoundMode::NearestEven, ea[i], eb[i], ec[i]).bits;
                    assert_eq!(eo[i], want, "{fmt} packed fma elem {i}");
                }

                packed::cma_words(fmt, &a, &b, &c, &mut out);
                let eo = unpack_all(&out);
                for i in 0..words * epw {
                    let p = mul(fmt, RoundMode::NearestEven, ea[i], eb[i]);
                    let want = add(fmt, RoundMode::NearestEven, p.bits, ec[i]).bits;
                    assert_eq!(eo[i], want, "{fmt} packed cma elem {i}");
                }

                packed::mul_words(fmt, &a, &b, &mut out);
                let eo = unpack_all(&out);
                for i in 0..words * epw {
                    let want = mul(fmt, RoundMode::NearestEven, ea[i], eb[i]).bits;
                    assert_eq!(eo[i], want, "{fmt} packed mul elem {i}");
                }

                packed::add_words(fmt, &a, &c, &mut out);
                let eo = unpack_all(&out);
                for i in 0..words * epw {
                    let want = add(fmt, RoundMode::NearestEven, ea[i], ec[i]).bits;
                    assert_eq!(eo[i], want, "{fmt} packed add elem {i}");
                }
            }
        }
    }

    #[test]
    fn fp8_saturation_and_small_format_overflow() {
        // FP8 E4M3 max is 240 under the IEEE-interchange convention this
        // stack uses (exp all-ones reserved for Inf/NaN, unlike OCP's
        // 448-max variant): 240·2 rounds to +Inf under RNE, never to
        // max-finite.
        let fmt = Format::FP8E4M3;
        let max = fmt.max_finite(false);
        assert_eq!(to_f64(fmt, max), 240.0);
        let two = from_f64(fmt, 2.0);
        let r = mul(fmt, RoundMode::NearestEven, max, two);
        assert_eq!(r.bits, fmt.inf(false));
        assert!(r.flags.overflow);
        // ...but toward-zero saturates at max-finite.
        let r = mul(fmt, RoundMode::TowardZero, max, two);
        assert_eq!(r.bits, max);
        // E5M2: max is 57344; adding half an ulp of max stays put (RNE).
        let fmt = Format::FP8E5M2;
        assert_eq!(to_f64(fmt, fmt.max_finite(false)), 57344.0);
    }

    #[test]
    fn dp_extreme_alignment() {
        // c is 2^1000 ulps away from the product: pure sticky path.
        let a = 2f64.powi(500);
        let b = 2f64.powi(400);
        let c = 1.0f64;
        assert!(same64(fma64(a, b, c), a.mul_add(b, c)));
        let c = -1.0f64;
        assert!(same64(fma64(a, b, c), a.mul_add(b, c)));
        // Near-total cancellation: a·b = 2^900, c = -2^900·(1+ε).
        let c = -(2f64.powi(900) * (1.0 + f64::EPSILON));
        assert!(same64(fma64(a, b, c), a.mul_add(b, c)));
    }
}
