//! Golden bit-exact IEEE-754 reference: multiply, add, and fused
//! multiply-add over raw bit patterns, in all four rounding modes.
//!
//! This is the *specification* the generated datapaths are tested against
//! (and, transitively, what the Pallas kernel and the AOT artifact are
//! checked against through the coordinator). It computes with exact
//! integer significand arithmetic (`u128` holds the 106-bit DP product
//! with room for alignment guards), then defers to
//! [`crate::arch::rounding::round_to_format`].
//!
//! The FMAC operation implemented is `a*b + c` — the paper's FMAC units
//! compute exactly this, with the FMA units rounding once and the CMA
//! units rounding after the multiply and again after the add (see
//! [`crate::arch::cma`]).

use super::fp::{bitlen128, decode, Class, Decoded, Format};
use super::rounding::{round_to_format, Flags, RoundMode, Rounded};

/// An exact unpacked finite value `(-1)^sign · sig · 2^exp` with a sticky
/// marker for discarded low-order bits (`value + (-1)^sign·ε`,
/// `0 ≤ ε < 2^exp`).
#[derive(Debug, Clone, Copy)]
pub struct Exact {
    pub sign: bool,
    pub exp: i32,
    pub sig: u128,
    pub sticky: bool,
}

impl Exact {
    /// Lift a decoded operand (finite classes only).
    pub fn from_decoded(d: &Decoded) -> Exact {
        Exact { sign: d.sign, exp: d.exp, sig: d.sig as u128, sticky: false }
    }

    /// Position of the value's MSB: value ∈ [2^(npos-1), 2^npos). Zero-sig
    /// values return i32::MIN.
    #[inline]
    pub fn npos(&self) -> i32 {
        if self.sig == 0 {
            i32::MIN
        } else {
            self.exp + bitlen128(self.sig) as i32
        }
    }
}

/// Exact product of two finite decoded operands (never overflows u128:
/// 53+53 = 106 bits).
pub fn mul_exact(a: &Decoded, b: &Decoded) -> Exact {
    Exact {
        sign: a.sign ^ b.sign,
        exp: a.exp + b.exp,
        sig: a.sig as u128 * b.sig as u128,
        sticky: false,
    }
}

/// Exact (sticky-summarized) sum of two unpacked values.
///
/// The result is exact except for a possible sticky residue from aligning
/// the far-smaller operand; the residue is strictly below the result's
/// LSB, which is all `round_to_format` needs for correct rounding in any
/// mode. The `mode` parameter only decides the sign of an exact-zero
/// cancellation result.
#[inline(always)]
pub fn add_exact(x: Exact, y: Exact, mode: RoundMode) -> Exact {
    debug_assert!(!x.sticky && !y.sticky, "inputs to add_exact must be exact");
    if x.sig == 0 {
        if y.sig == 0 {
            // ±0 + ±0: equal signs keep the sign, else mode-dependent.
            let sign = if x.sign == y.sign { x.sign } else { mode.cancellation_zero_sign() };
            return Exact { sign, exp: 0, sig: 0, sticky: false };
        }
        return y;
    }
    if y.sig == 0 {
        return x;
    }

    // Identify the operand with strictly larger magnitude (ties broken
    // after an exact aligned compare).
    let (big, small) = match cmp_magnitude(&x, &y) {
        std::cmp::Ordering::Greater => (x, y),
        std::cmp::Ordering::Less => (y, x),
        std::cmp::Ordering::Equal => {
            if x.sign != y.sign {
                // Exact cancellation.
                return Exact {
                    sign: mode.cancellation_zero_sign(),
                    exp: 0,
                    sig: 0,
                    sticky: false,
                };
            }
            (x, y)
        }
    };

    // Normalize `big` to the top of u128, leaving one bit of carry
    // headroom: MSB at bit 126.
    let lsh = 126 - (bitlen128(big.sig) - 1);
    let big_sig = big.sig << lsh;
    let big_exp = big.exp - lsh as i32;

    // Align `small` to big_exp.
    let d = big_exp - small.exp;
    let (small_sig, _round, sticky) = if d >= 0 {
        let (kept, r, s) = super::rounding::shift_right_rs(small.sig, d, false);
        // Fold the round bit back into sticky semantics by keeping it in
        // the kept value when possible: we instead keep one extra guard by
        // construction (big has headroom), so treat r as part of sticky.
        (kept, false, r || s)
    } else {
        // small's LSB sits above big_exp; shift left exactly (cannot
        // overflow: small's aligned length ≤ big's npos - big_exp = 127).
        (small.sig << (-d) as u32, false, false)
    };

    if big.sign == small.sign {
        Exact { sign: big.sign, exp: big_exp, sig: big_sig + small_sig, sticky }
    } else {
        // |big| > |small| strictly. If sticky, the true small is slightly
        // larger than small_sig: represent big - small as
        // (big_sig - small_sig - 1) + (1 - ε'), keeping sticky set.
        let sig = if sticky { big_sig - small_sig - 1 } else { big_sig - small_sig };
        Exact { sign: big.sign, exp: big_exp, sig, sticky }
    }
}

/// Compare |x| vs |y| exactly.
#[inline(always)]
fn cmp_magnitude(x: &Exact, y: &Exact) -> std::cmp::Ordering {
    let (nx, ny) = (x.npos(), y.npos());
    if nx != ny {
        return nx.cmp(&ny);
    }
    // Same MSB position: align both to the smaller exponent and compare.
    // Aligned lengths equal npos - min_exp = bitlen of the operand that
    // already sits at min_exp ≤ 128, so no overflow.
    let e = x.exp.min(y.exp);
    let xs = x.sig << (x.exp - e) as u32;
    let ys = y.sig << (y.exp - e) as u32;
    xs.cmp(&ys)
}

/// Round an exact value into `fmt` under `mode`.
#[inline(always)]
pub fn round(fmt: Format, mode: RoundMode, v: Exact) -> Rounded {
    if v.sig == 0 && !v.sticky {
        return Rounded { bits: fmt.zero(v.sign), flags: Flags::default() };
    }
    round_to_format(fmt, mode, v.sign, v.exp, v.sig, v.sticky)
}

/// Invalid-operation result: canonical qNaN with the invalid flag.
fn invalid(fmt: Format) -> Rounded {
    Rounded { bits: fmt.qnan(), flags: Flags { invalid: true, ..Flags::default() } }
}

/// Quiet-NaN result without the invalid flag (NaN propagation).
fn qnan(fmt: Format) -> Rounded {
    Rounded { bits: fmt.qnan(), flags: Flags::default() }
}

/// IEEE-754 fused multiply-add: `round(a·b + c)` with a single rounding.
///
/// Special-case semantics follow IEEE 754-2019 §7.2: any NaN operand
/// propagates; `(±Inf)·(±0)` is invalid even when `c` is NaN per the
/// standard's option exercised by x86/ARM (we return qNaN either way, so
/// datapath comparisons are unaffected).
pub fn fma(fmt: Format, mode: RoundMode, a_bits: u64, b_bits: u64, c_bits: u64) -> Rounded {
    let a = decode(fmt, a_bits);
    let b = decode(fmt, b_bits);
    let c = decode(fmt, c_bits);

    // NaN propagation / invalid detection.
    let prod_invalid = (a.class == Class::Infinity && b.is_zero())
        || (b.class == Class::Infinity && a.is_zero());
    if a.class == Class::Nan || b.class == Class::Nan || c.class == Class::Nan {
        if prod_invalid {
            return invalid(fmt);
        }
        return qnan(fmt);
    }
    if prod_invalid {
        return invalid(fmt);
    }

    let psign = a.sign ^ b.sign;
    let pinf = a.class == Class::Infinity || b.class == Class::Infinity;
    match (pinf, c.class == Class::Infinity) {
        (true, true) => {
            if psign != c.sign {
                return invalid(fmt); // Inf - Inf
            }
            return Rounded { bits: fmt.inf(psign), flags: Flags::default() };
        }
        (true, false) => return Rounded { bits: fmt.inf(psign), flags: Flags::default() },
        (false, true) => return Rounded { bits: fmt.inf(c.sign), flags: Flags::default() },
        (false, false) => {}
    }

    // Finite path.
    let p = mul_exact(&a, &b);
    if p.sig == 0 && c.is_zero() {
        // ±0 + ±0 sign rules.
        let sign = if p.sign == c.sign { p.sign } else { mode.cancellation_zero_sign() };
        return Rounded { bits: fmt.zero(sign), flags: Flags::default() };
    }
    let sum = add_exact(p, Exact::from_decoded(&c), mode);
    round(fmt, mode, sum)
}

/// IEEE-754 multiply: `round(a·b)`.
pub fn mul(fmt: Format, mode: RoundMode, a_bits: u64, b_bits: u64) -> Rounded {
    let a = decode(fmt, a_bits);
    let b = decode(fmt, b_bits);
    if a.class == Class::Nan || b.class == Class::Nan {
        return qnan(fmt);
    }
    if (a.class == Class::Infinity && b.is_zero()) || (b.class == Class::Infinity && a.is_zero())
    {
        return invalid(fmt);
    }
    let sign = a.sign ^ b.sign;
    if a.class == Class::Infinity || b.class == Class::Infinity {
        return Rounded { bits: fmt.inf(sign), flags: Flags::default() };
    }
    if a.is_zero() || b.is_zero() {
        return Rounded { bits: fmt.zero(sign), flags: Flags::default() };
    }
    round(fmt, mode, mul_exact(&a, &b))
}

/// IEEE-754 add: `round(a + c)`.
pub fn add(fmt: Format, mode: RoundMode, a_bits: u64, c_bits: u64) -> Rounded {
    let a = decode(fmt, a_bits);
    let c = decode(fmt, c_bits);
    if a.class == Class::Nan || c.class == Class::Nan {
        return qnan(fmt);
    }
    match (a.class == Class::Infinity, c.class == Class::Infinity) {
        (true, true) => {
            if a.sign != c.sign {
                return invalid(fmt);
            }
            return Rounded { bits: fmt.inf(a.sign), flags: Flags::default() };
        }
        (true, false) => return Rounded { bits: fmt.inf(a.sign), flags: Flags::default() },
        (false, true) => return Rounded { bits: fmt.inf(c.sign), flags: Flags::default() },
        (false, false) => {}
    }
    let sum = add_exact(Exact::from_decoded(&a), Exact::from_decoded(&c), mode);
    round(fmt, mode, sum)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fma32(a: f32, b: f32, c: f32) -> f32 {
        f32::from_bits(
            fma(
                Format::SP,
                RoundMode::NearestEven,
                a.to_bits() as u64,
                b.to_bits() as u64,
                c.to_bits() as u64,
            )
            .bits as u32,
        )
    }

    fn fma64(a: f64, b: f64, c: f64) -> f64 {
        f64::from_bits(
            fma(Format::DP, RoundMode::NearestEven, a.to_bits(), b.to_bits(), c.to_bits()).bits,
        )
    }

    fn same32(x: f32, y: f32) -> bool {
        (x.is_nan() && y.is_nan()) || x.to_bits() == y.to_bits()
    }

    fn same64(x: f64, y: f64) -> bool {
        (x.is_nan() && y.is_nan()) || x.to_bits() == y.to_bits()
    }

    #[test]
    fn fma_simple_values() {
        assert_eq!(fma32(1.5, 2.0, 0.25), 3.25);
        assert_eq!(fma32(-1.5, 2.0, 0.25), -2.75);
        assert_eq!(fma64(1.5, 2.0, 0.25), 3.25);
        assert_eq!(fma32(0.0, 5.0, 7.0), 7.0);
    }

    #[test]
    fn fma_is_single_rounding() {
        // Classic fused-vs-cascade discriminator: a·b lands exactly between
        // two representable values and c nudges it; a two-rounding cascade
        // gets it wrong. (1 + 2^-12)^2 = 1 + 2^-11 + 2^-24.
        let a = 1.0f32 + f32::EPSILON * 2048.0; // 1 + 2^-12
        let c = -(1.0f32 + 2.0 * f32::EPSILON * 2048.0); // -(1 + 2^-11)
        let fused = fma32(a, a, c);
        assert_eq!(fused, 2f32.powi(-24));
        // Cascade result for comparison: round(a·a) = 1 + 2^-11 (the 2^-24
        // is rounded away as a tie-to-even), so cascade gives exactly 0.
        let r1 = mul(Format::SP, RoundMode::NearestEven, a.to_bits() as u64, a.to_bits() as u64);
        let r2 = add(Format::SP, RoundMode::NearestEven, r1.bits, c.to_bits() as u64);
        assert_eq!(f32::from_bits(r2.bits as u32), 0.0);
    }

    #[test]
    fn fma_matches_hardware_exhaustive_smallset() {
        // Deterministic structured operands: all sign/exponent-extreme
        // combinations of a small value set.
        let vals = [
            0.0f32,
            -0.0,
            1.0,
            -1.0,
            1.5,
            f32::MIN_POSITIVE,
            -f32::MIN_POSITIVE,
            f32::MIN_POSITIVE / 4.0, // subnormal
            f32::MAX,
            -f32::MAX,
            f32::INFINITY,
            f32::NEG_INFINITY,
            f32::NAN,
            f32::EPSILON,
            2f32.powi(-149),
            3.4028e38,
        ];
        for &a in &vals {
            for &b in &vals {
                for &c in &vals {
                    let got = fma32(a, b, c);
                    let want = a.mul_add(b, c);
                    assert!(
                        same32(got, want),
                        "fma({a:e},{b:e},{c:e}) = {got:e}, want {want:e}"
                    );
                }
            }
        }
    }

    #[test]
    fn fma_matches_hardware_dp_smallset() {
        let vals = [
            0.0f64,
            -0.0,
            1.0,
            -1.0,
            1.0 + f64::EPSILON,
            f64::MIN_POSITIVE,
            f64::MIN_POSITIVE / 8.0,
            f64::MAX,
            -f64::MAX,
            f64::INFINITY,
            f64::NEG_INFINITY,
            f64::NAN,
            2f64.powi(-1074),
            -2f64.powi(-1074),
        ];
        for &a in &vals {
            for &b in &vals {
                for &c in &vals {
                    let got = fma64(a, b, c);
                    let want = a.mul_add(b, c);
                    assert!(
                        same64(got, want),
                        "fma({a:e},{b:e},{c:e}) = {got:e}, want {want:e}"
                    );
                }
            }
        }
    }

    #[test]
    fn cancellation_zero_signs() {
        // 1·1 + (-1) = +0 under RNE, -0 under RD.
        let r = fma(Format::SP, RoundMode::NearestEven, 0x3f80_0000, 0x3f80_0000, 0xbf80_0000);
        assert_eq!(r.bits, 0);
        let r = fma(Format::SP, RoundMode::TowardNegative, 0x3f80_0000, 0x3f80_0000, 0xbf80_0000);
        assert_eq!(r.bits as u32, (-0.0f32).to_bits());
        // (+0)·1 + (+0) keeps +0; (+0)·1 + (-0) is +0 under RNE.
        let r = fma32(0.0, 1.0, 0.0);
        assert_eq!(r.to_bits(), 0);
        let r = fma32(0.0, 1.0, -0.0);
        assert_eq!(r.to_bits(), 0);
        // (-0)·1 + (-0) = -0.
        let r = fma32(-0.0, 1.0, -0.0);
        assert_eq!(r.to_bits(), (-0.0f32).to_bits());
    }

    #[test]
    fn invalid_cases() {
        let f = Format::SP;
        let inf = f32::INFINITY.to_bits() as u64;
        let zero = 0u64;
        let one = 1.0f32.to_bits() as u64;
        // Inf · 0
        assert!(fma(f, RoundMode::NearestEven, inf, zero, one).flags.invalid);
        // Inf - Inf through the addend
        let ninf = f32::NEG_INFINITY.to_bits() as u64;
        assert!(fma(f, RoundMode::NearestEven, inf, one, ninf).flags.invalid);
        // Inf · 0 + NaN is still invalid (we exercise the x86 option)
        let nan = f32::NAN.to_bits() as u64;
        assert!(fma(f, RoundMode::NearestEven, inf, zero, nan).flags.invalid);
        // Plain NaN propagation is not invalid.
        assert!(!fma(f, RoundMode::NearestEven, nan, one, one).flags.invalid);
    }

    #[test]
    fn subnormal_results() {
        // Product of two tiny normals lands in the subnormal range.
        let a = f32::MIN_POSITIVE; // 2^-126
        let b = 0.5f32;
        let got = fma32(a, b, 0.0);
        assert_eq!(got, a.mul_add(b, 0.0));
        assert_eq!(got, 2f32.powi(-127));
        // Subnormal × subnormal underflows to zero (RNE). (Constructed via
        // from_bits: powi(-140) itself underflows through its reciprocal.)
        let s = f32::from_bits(1 << 9); // 2^-140
        assert_eq!(fma32(s, s, 0.0), 0.0);
        // ... but toward-positive gives min subnormal.
        let r = fma(
            Format::SP,
            RoundMode::TowardPositive,
            s.to_bits() as u64,
            s.to_bits() as u64,
            0,
        );
        assert_eq!(r.bits, 1);
    }

    #[test]
    fn add_exact_sticky_subtraction() {
        // x = 1.0, y = -(2^-100): result must be just under 1.0 → the
        // largest float < 1.0 under RZ, and 1.0 under RNE.
        let one = 1.0f32.to_bits() as u64;
        let tiny = (2f32.powi(-100)).to_bits() as u64 | (1u64 << 31);
        let rz = add(Format::SP, RoundMode::TowardZero, one, tiny);
        assert_eq!(f32::from_bits(rz.bits as u32), 1.0 - f32::EPSILON / 2.0);
        let rn = add(Format::SP, RoundMode::NearestEven, one, tiny);
        assert_eq!(f32::from_bits(rn.bits as u32), 1.0);
        assert!(rn.flags.inexact);
    }

    #[test]
    fn mul_add_flags() {
        // Overflow flag.
        let r = mul(
            Format::SP,
            RoundMode::NearestEven,
            f32::MAX.to_bits() as u64,
            2.0f32.to_bits() as u64,
        );
        assert!(r.flags.overflow);
        assert_eq!(r.bits as u32, f32::INFINITY.to_bits());
        // Exact operations raise nothing.
        let r = mul(Format::SP, RoundMode::NearestEven, 3.0f32.to_bits() as u64, 0.5f32.to_bits() as u64);
        assert_eq!(r.flags, Flags::default());
    }

    #[test]
    fn dp_extreme_alignment() {
        // c is 2^1000 ulps away from the product: pure sticky path.
        let a = 2f64.powi(500);
        let b = 2f64.powi(400);
        let c = 1.0f64;
        assert!(same64(fma64(a, b, c), a.mul_add(b, c)));
        let c = -1.0f64;
        assert!(same64(fma64(a, b, c), a.mul_add(b, c)));
        // Near-total cancellation: a·b = 2^900, c = -2^900·(1+ε).
        let c = -(2f64.powi(900) * (1.0 + f64::EPSILON));
        assert!(same64(fma64(a, b, c), a.mul_add(b, c)));
    }
}
