//! The significand multiplier: Booth recoder + partial-product array +
//! reduction tree + (optional) final carry-propagate adder.
//!
//! This is the block FPGen varies most between the four FPMax units, and
//! the dominant area/energy term of every FMAC. The multiplier produces
//! its result in **carry-save form** so the FMA datapath can merge the
//! addend before any carry propagation; the CMA's multiplier resolves
//! through its own CPA and rounder instead.


use super::booth::{BoothRadix, PpStats};
use super::csa::{CarrySave, CsaStats};
use super::tree::TreeKind;

/// Static multiplier configuration (a slice of [`crate::arch::FpuConfig`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct MultiplierConfig {
    /// Significand width in bits (24 for SP, 53 for DP).
    pub sig_bits: u32,
    pub booth: BoothRadix,
    pub tree: TreeKind,
}

impl MultiplierConfig {
    /// Number of partial products the Booth stage emits.
    pub fn pp_count(&self) -> u32 {
        self.booth.digit_count(self.sig_bits)
    }

    /// Window width of the PP array / tree datapath: the full product plus
    /// two guard bits for the Booth negate carries.
    pub fn window(&self) -> u32 {
        2 * self.sig_bits + 2
    }

    /// Reduction-tree depth in 3:2 levels.
    pub fn tree_depth(&self) -> u32 {
        self.tree.depth_levels(self.pp_count())
    }
}

/// Dynamic per-operation result: the product in carry-save form plus the
/// activity observed while computing it.
#[derive(Debug, Clone, Copy)]
pub struct MulResult {
    /// Redundant product; `resolve(window)` yields the exact product.
    pub cs: CarrySave,
    /// Booth-stage statistics for this operand pair.
    pub pp_stats: PpStats,
    /// Tree statistics for this operand pair.
    pub tree_stats: CsaStats,
}

impl MulResult {
    /// Resolve the carry-save product through the CPA.
    pub fn product(&self, cfg: &MultiplierConfig) -> u128 {
        self.cs.resolve(cfg.window())
    }
}

/// Multiply two unsigned significands through the configured structure.
///
/// The result is exact: Booth recoding and carry-save reduction are
/// lossless mod 2^window, and the window is wide enough for the full
/// product (asserted in debug builds).
pub fn multiply(cfg: &MultiplierConfig, x: u64, y: u64) -> MulResult {
    multiply_t::<true>(cfg, x, y)
}

/// Multiplication generic over activity tracking: the verification hot
/// path (`FpuUnit::fmac`) uses `TRACK = false`, which compiles out the
/// Booth digit statistics and every CSA toggle count.
#[inline(always)]
pub fn multiply_t<const TRACK: bool>(cfg: &MultiplierConfig, x: u64, y: u64) -> MulResult {
    let width = cfg.window();
    // Size the PP buffer to the configuration (zero-initializing the full
    // 28-slot worst case costs ~15% on the 9-PP SP hot path).
    let (cs, pp_stats, tree_stats) = if cfg.pp_count() <= 18 {
        multiply_inner::<TRACK, 18>(cfg, x, y, width)
    } else {
        multiply_inner::<TRACK, { crate::arch::booth::MAX_PPS }>(cfg, x, y, width)
    };
    let out = MulResult { cs, pp_stats, tree_stats };
    debug_assert_eq!(
        out.product(cfg),
        x as u128 * y as u128,
        "structural multiplier diverged from x·y: cfg={cfg:?} x={x:#x} y={y:#x}"
    );
    out
}

#[inline(always)]
fn multiply_inner<const TRACK: bool, const CAP: usize>(
    cfg: &MultiplierConfig,
    x: u64,
    y: u64,
    width: u32,
) -> (CarrySave, PpStats, CsaStats) {
    let mut buf = [0u128; CAP];
    let (n, pp_stats) =
        crate::arch::booth::partial_products_into(x, y, cfg.sig_bits, cfg.booth, width, &mut buf);
    let mut tree_stats = CsaStats::default();
    let cs = cfg.tree.reduce_t::<TRACK>(&buf[..n], width, &mut tree_stats);
    (cs, pp_stats, tree_stats)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn all_configs(sig_bits: u32) -> Vec<MultiplierConfig> {
        let mut v = Vec::new();
        for booth in [BoothRadix::Booth2, BoothRadix::Booth3] {
            for tree in [TreeKind::Wallace, TreeKind::Array, TreeKind::Zm] {
                v.push(MultiplierConfig { sig_bits, booth, tree });
            }
        }
        v
    }

    #[test]
    fn exact_products_sp() {
        let vals = [0u64, 1, 2, (1 << 23), (1 << 24) - 1, 0x00c0_ffee, 0x00ab_cdef];
        for cfg in all_configs(24) {
            for &x in &vals {
                for &y in &vals {
                    let r = multiply(&cfg, x, y);
                    assert_eq!(r.product(&cfg), x as u128 * y as u128, "{cfg:?}");
                }
            }
        }
    }

    #[test]
    fn exact_products_dp() {
        let m53 = (1u64 << 53) - 1;
        let vals = [0u64, 1, 1 << 52, m53, 0x0015_5555_5555_5555, 0x001f_0f0f_0f0f_0f0f & m53];
        for cfg in all_configs(53) {
            for &x in &vals {
                for &y in &vals {
                    let r = multiply(&cfg, x, y);
                    assert_eq!(r.product(&cfg), x as u128 * y as u128, "{cfg:?}");
                }
            }
        }
    }

    #[test]
    fn paper_configurations_structure() {
        // SP FMA: Booth-3 + ZM over 9 PPs.
        let sp_fma = MultiplierConfig { sig_bits: 24, booth: BoothRadix::Booth3, tree: TreeKind::Zm };
        assert_eq!(sp_fma.pp_count(), 9);
        assert_eq!(sp_fma.window(), 50);
        // SP CMA: Booth-2 + Wallace over 13 PPs, depth 5.
        let sp_cma = MultiplierConfig { sig_bits: 24, booth: BoothRadix::Booth2, tree: TreeKind::Wallace };
        assert_eq!(sp_cma.pp_count(), 13);
        assert_eq!(sp_cma.tree_depth(), 5);
        // DP CMA: Booth-3 + Wallace over 18 PPs, depth 6.
        let dp_cma = MultiplierConfig { sig_bits: 53, booth: BoothRadix::Booth3, tree: TreeKind::Wallace };
        assert_eq!(dp_cma.pp_count(), 18);
        assert_eq!(dp_cma.tree_depth(), 6);
        // DP FMA: Booth-3 + Array over 18 PPs, depth 16.
        let dp_fma = MultiplierConfig { sig_bits: 53, booth: BoothRadix::Booth3, tree: TreeKind::Array };
        assert_eq!(dp_fma.tree_depth(), 16);
    }

    #[test]
    fn booth3_smaller_tree_than_booth2() {
        // The Table-I rationale: Booth-3 cuts PP count ~33%, shrinking
        // whichever tree follows.
        for m in [24, 53] {
            let b2 = BoothRadix::Booth2.digit_count(m);
            let b3 = BoothRadix::Booth3.digit_count(m);
            assert!(b3 * 3 <= b2 * 2 + 2, "m={m}: b2={b2} b3={b3}");
        }
    }

    #[test]
    fn activity_scales_with_operand_density() {
        // All-zeros multiplier ⇒ near-zero toggles; dense operands ⇒ many.
        let cfg = MultiplierConfig { sig_bits: 24, booth: BoothRadix::Booth2, tree: TreeKind::Wallace };
        let quiet = multiply(&cfg, 0xffffff, 0);
        let busy = multiply(&cfg, 0xffffff, 0xaaaaaa);
        assert!(quiet.tree_stats.toggles < busy.tree_stats.toggles / 4);
    }
}
