//! IEEE-754 binary interchange format codecs (SP / DP / FP16 / BF16 /
//! FP8).
//!
//! Every datapath in this crate works on raw bit patterns (`u64`, with
//! sub-64-bit formats occupying the low bits) so the same code drives
//! every precision — exactly how FPGen parameterizes its generated RTL
//! over `(exp_bits, man_bits)`. This module owns unpacking to
//! sign/exponent/significand triples, classification, and packing
//! (including subnormal and overflow handling at encode time via
//! [`crate::arch::rounding`]).
//!
//! The transprecision tier set follows FPnew: alongside binary32/64 the
//! stack carries `binary16`, `bfloat16`, and the two FP8 flavors
//! (E4M3/E5M2). All are treated IEEE-interchange-style — the all-ones
//! exponent encodes Inf/NaN even for E4M3, where OCP's variant spends
//! that binade on finite values; the uniform treatment keeps one
//! decode/encode/rounding path for every format, and the differential
//! engines all agree on it by construction.


/// Operand precision of a generated FPU.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Precision {
    /// IEEE binary32.
    Single,
    /// IEEE binary64.
    Double,
    /// IEEE binary16.
    Half,
    /// bfloat16 (binary32 exponent range, 8-bit significand).
    Bfloat16,
    /// FP8 E4M3 (IEEE-interchange-style specials — see module docs).
    Fp8E4M3,
    /// FP8 E5M2.
    Fp8E5M2,
}

impl Precision {
    /// Every supported precision, SP/DP first (their positions are
    /// load-bearing for [`crate::runtime::router::WorkloadClass`]
    /// indexing), then the transprecision tiers widest-first.
    pub const ALL: [Precision; 6] = [
        Precision::Single,
        Precision::Double,
        Precision::Half,
        Precision::Bfloat16,
        Precision::Fp8E4M3,
        Precision::Fp8E5M2,
    ];

    /// The format descriptor for this precision.
    pub fn format(self) -> Format {
        match self {
            Precision::Single => Format::SP,
            Precision::Double => Format::DP,
            Precision::Half => Format::FP16,
            Precision::Bfloat16 => Format::BF16,
            Precision::Fp8E4M3 => Format::FP8E4M3,
            Precision::Fp8E5M2 => Format::FP8E5M2,
        }
    }

    /// Short lowercase name used in reports, artifact paths, CLI flags,
    /// and JSON schemas — the one canonical spelling per format.
    pub fn name(self) -> &'static str {
        match self {
            Precision::Single => "sp",
            Precision::Double => "dp",
            Precision::Half => "fp16",
            Precision::Bfloat16 => "bf16",
            Precision::Fp8E4M3 => "fp8e4m3",
            Precision::Fp8E5M2 => "fp8e5m2",
        }
    }

    /// Parse the canonical spelling produced by [`Precision::name`]
    /// (case-insensitive). The CLI, JSON schemas, and the CI checker all
    /// round-trip through this pair.
    pub fn parse(s: &str) -> Option<Precision> {
        let lower = s.to_ascii_lowercase();
        Precision::ALL.into_iter().find(|p| p.name() == lower)
    }
}

impl std::fmt::Display for Precision {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// An IEEE-754 binary format described by its field widths.
///
/// `sig_bits` counts the significand *including* the hidden bit (24 for SP,
/// 53 for DP), matching the width of the datapath's significand buses.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Format {
    /// Exponent field width in bits.
    pub exp_bits: u32,
    /// Significand width in bits, including the hidden bit.
    pub sig_bits: u32,
}

impl Format {
    /// IEEE binary32.
    pub const SP: Format = Format { exp_bits: 8, sig_bits: 24 };
    /// IEEE binary64.
    pub const DP: Format = Format { exp_bits: 11, sig_bits: 53 };
    /// IEEE binary16.
    pub const FP16: Format = Format { exp_bits: 5, sig_bits: 11 };
    /// bfloat16: binary32's exponent range, truncated significand.
    pub const BF16: Format = Format { exp_bits: 8, sig_bits: 8 };
    /// FP8 E4M3 (IEEE-interchange specials — see module docs).
    pub const FP8E4M3: Format = Format { exp_bits: 4, sig_bits: 4 };
    /// FP8 E5M2.
    pub const FP8E5M2: Format = Format { exp_bits: 5, sig_bits: 3 };

    /// Every supported format, in [`Precision::ALL`] order.
    pub fn all() -> [Format; 6] {
        [
            Format::SP,
            Format::DP,
            Format::FP16,
            Format::BF16,
            Format::FP8E4M3,
            Format::FP8E5M2,
        ]
    }

    /// The [`Precision`] tag for this format, if it is one of the six
    /// supported tiers.
    pub fn precision(&self) -> Option<Precision> {
        Precision::ALL.into_iter().find(|p| p.format() == *self)
    }

    /// Canonical lowercase name (shared with [`Precision::name`]);
    /// `"e{exp}m{man}"` for formats outside the supported set.
    pub fn name(&self) -> &'static str {
        match self.precision() {
            Some(p) => p.name(),
            None => "custom",
        }
    }

    /// Parse the canonical spelling back into a format descriptor.
    pub fn parse(s: &str) -> Option<Format> {
        Precision::parse(s).map(|p| p.format())
    }

    /// Total storage width (1 + exp + fraction).
    pub const fn width(&self) -> u32 {
        1 + self.exp_bits + self.sig_bits - 1
    }

    /// Exponent bias.
    pub const fn bias(&self) -> i32 {
        (1 << (self.exp_bits - 1)) - 1
    }

    /// Maximum biased exponent value (all ones; Inf/NaN marker).
    pub const fn emax_biased(&self) -> u64 {
        (1 << self.exp_bits) - 1
    }

    /// Minimum normal (unbiased) exponent of the *value's* MSB, e.g. -126
    /// for SP.
    pub const fn emin(&self) -> i32 {
        1 - self.bias()
    }

    /// Maximum normal (unbiased) exponent of the value's MSB, e.g. 127 for
    /// SP.
    pub const fn emax(&self) -> i32 {
        self.bias()
    }

    /// Exponent of the least significant bit of subnormals (the minimum
    /// quantum), e.g. -149 for SP.
    pub const fn qmin(&self) -> i32 {
        self.emin() - (self.sig_bits as i32 - 1)
    }

    /// Fraction-field mask.
    pub const fn frac_mask(&self) -> u64 {
        (1u64 << (self.sig_bits - 1)) - 1
    }

    /// Hidden-bit position value.
    pub const fn hidden_bit(&self) -> u64 {
        1u64 << (self.sig_bits - 1)
    }

    /// Mask of all storage bits.
    pub const fn storage_mask(&self) -> u64 {
        if self.width() == 64 {
            u64::MAX
        } else {
            (1u64 << self.width()) - 1
        }
    }

    /// Sign-bit position value.
    pub const fn sign_bit(&self) -> u64 {
        1u64 << (self.width() - 1)
    }

    /// The canonical quiet NaN (sign 0, exponent all-ones, MSB of fraction
    /// set) — what the datapaths emit for any invalid operation.
    pub const fn qnan(&self) -> u64 {
        (self.emax_biased() << (self.sig_bits - 1)) | (1u64 << (self.sig_bits - 2))
    }

    /// Positive infinity bit pattern.
    pub const fn inf(&self, sign: bool) -> u64 {
        let mag = self.emax_biased() << (self.sig_bits - 1);
        if sign {
            mag | self.sign_bit()
        } else {
            mag
        }
    }

    /// Largest finite magnitude (used by directed rounding on overflow).
    pub const fn max_finite(&self, sign: bool) -> u64 {
        let mag = ((self.emax_biased() - 1) << (self.sig_bits - 1)) | self.frac_mask();
        if sign {
            mag | self.sign_bit()
        } else {
            mag
        }
    }

    /// Zero of the given sign.
    pub const fn zero(&self, sign: bool) -> u64 {
        if sign {
            self.sign_bit()
        } else {
            0
        }
    }
}

impl std::fmt::Display for Format {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self.precision() {
            Some(p) => f.write_str(p.name()),
            None => write!(f, "e{}m{}", self.exp_bits, self.sig_bits - 1),
        }
    }
}

/// Classification of a decoded operand.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Class {
    Zero,
    Subnormal,
    Normal,
    Infinity,
    Nan,
}

/// A decoded floating-point operand.
///
/// For finite nonzero values, `value = (-1)^sign × sig × 2^exp` exactly,
/// with `sig` the integer significand (hidden bit included for normals).
/// `exp` is the exponent of the significand's **LSB**, not of the value's
/// MSB — this is the natural fixed-point view the datapath buses use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Decoded {
    pub sign: bool,
    /// Exponent of the significand LSB (value = sig · 2^exp).
    pub exp: i32,
    /// Integer significand; in `[2^(sig_bits-1), 2^sig_bits)` for normals,
    /// `(0, 2^(sig_bits-1))` for subnormals, `0` for zeros.
    pub sig: u64,
    pub class: Class,
}

impl Decoded {
    /// True for Inf or NaN.
    pub fn non_finite(&self) -> bool {
        matches!(self.class, Class::Infinity | Class::Nan)
    }

    /// True for +0 or -0.
    pub fn is_zero(&self) -> bool {
        self.class == Class::Zero
    }
}

/// Decode a raw bit pattern in `fmt` into sign/exponent/significand.
#[inline(always)]
pub fn decode(fmt: Format, bits: u64) -> Decoded {
    let bits = bits & fmt.storage_mask();
    let sign = bits & fmt.sign_bit() != 0;
    let biased = (bits >> (fmt.sig_bits - 1)) & fmt.emax_biased();
    let frac = bits & fmt.frac_mask();
    if biased == fmt.emax_biased() {
        let class = if frac == 0 { Class::Infinity } else { Class::Nan };
        return Decoded { sign, exp: 0, sig: frac, class };
    }
    if biased == 0 {
        if frac == 0 {
            return Decoded { sign, exp: 0, sig: 0, class: Class::Zero };
        }
        // Subnormal: hidden bit absent, exponent pinned at emin.
        return Decoded { sign, exp: fmt.qmin(), sig: frac, class: Class::Subnormal };
    }
    Decoded {
        sign,
        exp: biased as i32 - fmt.bias() - (fmt.sig_bits as i32 - 1),
        sig: frac | fmt.hidden_bit(),
        class: Class::Normal,
    }
}

/// Encode a *normalized* finite result back to bits.
///
/// `sig` must already sit in the canonical range for its class (this is
/// what [`crate::arch::rounding::round_to_format`] produces); `exp` is the
/// LSB exponent. Panics on out-of-range inputs — rounding owns range
/// reduction, encoding must be exact.
pub fn encode_finite(fmt: Format, sign: bool, exp: i32, sig: u64) -> u64 {
    let s = if sign { fmt.sign_bit() } else { 0 };
    if sig == 0 {
        return s;
    }
    assert!(sig < (1u64 << fmt.sig_bits), "significand overflows format");
    if sig & fmt.hidden_bit() == 0 {
        // Subnormal: exponent must be pinned at qmin.
        assert_eq!(exp, fmt.qmin(), "subnormal significand at wrong exponent");
        return s | sig;
    }
    let biased = exp + fmt.bias() + (fmt.sig_bits as i32 - 1);
    assert!(
        biased >= 1 && (biased as u64) < fmt.emax_biased(),
        "exponent {biased} out of range"
    );
    s | ((biased as u64) << (fmt.sig_bits - 1)) | (sig & fmt.frac_mask())
}

/// Number of significant bits in `x` (position of MSB + 1; 0 for 0).
#[inline]
pub fn bitlen64(x: u64) -> u32 {
    64 - x.leading_zeros()
}

/// Number of significant bits in `x` (u128 variant).
#[inline]
pub fn bitlen128(x: u128) -> u32 {
    128 - x.leading_zeros()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn format_constants_sp() {
        let f = Format::SP;
        assert_eq!(f.width(), 32);
        assert_eq!(f.bias(), 127);
        assert_eq!(f.emin(), -126);
        assert_eq!(f.emax(), 127);
        assert_eq!(f.qmin(), -149);
        assert_eq!(f.hidden_bit(), 1 << 23);
        assert_eq!(f.frac_mask(), (1 << 23) - 1);
        assert_eq!(f.sign_bit(), 1 << 31);
        assert_eq!(f.inf(false), 0x7f80_0000);
        assert_eq!(f.inf(true), 0xff80_0000);
        assert_eq!(f.qnan(), 0x7fc0_0000);
        assert_eq!(f.max_finite(false), 0x7f7f_ffff);
    }

    #[test]
    fn format_constants_dp() {
        let f = Format::DP;
        assert_eq!(f.width(), 64);
        assert_eq!(f.bias(), 1023);
        assert_eq!(f.emin(), -1022);
        assert_eq!(f.qmin(), -1074);
        assert_eq!(f.inf(false), 0x7ff0_0000_0000_0000);
        assert_eq!(f.qnan(), 0x7ff8_0000_0000_0000);
        assert_eq!(f.max_finite(true), 0xffef_ffff_ffff_ffff);
        assert_eq!(f.storage_mask(), u64::MAX);
    }

    #[test]
    fn format_constants_small() {
        let f = Format::FP16;
        assert_eq!(f.width(), 16);
        assert_eq!(f.bias(), 15);
        assert_eq!(f.emin(), -14);
        assert_eq!(f.emax(), 15);
        assert_eq!(f.qmin(), -24);
        assert_eq!(f.inf(false), 0x7c00);
        assert_eq!(f.qnan(), 0x7e00);
        assert_eq!(f.max_finite(false), 0x7bff);
        assert_eq!(f.storage_mask(), 0xffff);

        let f = Format::BF16;
        assert_eq!(f.width(), 16);
        assert_eq!(f.bias(), 127);
        assert_eq!(f.emin(), -126);
        assert_eq!(f.qmin(), -133);
        assert_eq!(f.inf(false), 0x7f80);
        assert_eq!(f.qnan(), 0x7fc0);
        assert_eq!(f.max_finite(false), 0x7f7f);

        let f = Format::FP8E4M3;
        assert_eq!(f.width(), 8);
        assert_eq!(f.bias(), 7);
        assert_eq!(f.qmin(), -9);
        assert_eq!(f.inf(false), 0x78);
        assert_eq!(f.qnan(), 0x7c);
        assert_eq!(f.max_finite(false), 0x77);

        let f = Format::FP8E5M2;
        assert_eq!(f.width(), 8);
        assert_eq!(f.bias(), 15);
        assert_eq!(f.qmin(), -16);
        assert_eq!(f.inf(false), 0x7c);
        assert_eq!(f.qnan(), 0x7e);
        assert_eq!(f.max_finite(false), 0x7b);
    }

    #[test]
    fn precision_format_name_parse_roundtrip_exhaustive() {
        // One canonical spelling per format, shared by CLI flags, JSON
        // schemas, and the CI checker: every hop of the round trip must
        // be the identity, for every supported tier.
        assert_eq!(Precision::ALL.len(), Format::all().len());
        for (p, f) in Precision::ALL.into_iter().zip(Format::all()) {
            assert_eq!(p.format(), f);
            assert_eq!(f.precision(), Some(p));
            assert_eq!(p.name(), f.name());
            assert_eq!(Precision::parse(p.name()), Some(p));
            assert_eq!(Format::parse(f.name()), Some(f));
            // Case-insensitive parse, exact Display.
            assert_eq!(Precision::parse(&p.name().to_uppercase()), Some(p));
            assert_eq!(format!("{p}"), p.name());
            assert_eq!(format!("{f}"), f.name());
        }
        // Names are pairwise distinct.
        for a in Precision::ALL {
            for b in Precision::ALL {
                assert_eq!(a.name() == b.name(), a == b);
            }
        }
        // Unknown spellings reject; non-canonical formats display raw.
        assert_eq!(Precision::parse("half"), None);
        assert_eq!(Format::parse("e4m3"), None);
        assert_eq!(Precision::parse(""), None);
        let odd = Format { exp_bits: 6, sig_bits: 10 };
        assert_eq!(odd.precision(), None);
        assert_eq!(format!("{odd}"), "e6m9");
        assert_eq!(odd.name(), "custom");
    }

    #[test]
    fn decode_classes_sp() {
        let f = Format::SP;
        assert_eq!(decode(f, 0).class, Class::Zero);
        assert_eq!(decode(f, f.sign_bit()).class, Class::Zero);
        assert!(decode(f, f.sign_bit()).sign);
        assert_eq!(decode(f, 1).class, Class::Subnormal);
        assert_eq!(decode(f, 0x0070_0000).class, Class::Subnormal);
        assert_eq!(decode(f, 0x3f80_0000).class, Class::Normal);
        assert_eq!(decode(f, 0x7f80_0000).class, Class::Infinity);
        assert_eq!(decode(f, 0x7fc0_0000).class, Class::Nan);
        assert_eq!(decode(f, 0xff80_0001).class, Class::Nan);
    }

    #[test]
    fn decode_value_semantics() {
        let f = Format::SP;
        // 1.0f32: sig = 2^23, exp = -23 → 2^23 · 2^-23 = 1.
        let d = decode(f, 1.0f32.to_bits() as u64);
        assert_eq!(d.sig, 1 << 23);
        assert_eq!(d.exp, -23);
        // 3.0f32 = 1.5 · 2 = (3·2^22) · 2^-22.
        let d = decode(f, 3.0f32.to_bits() as u64);
        assert_eq!(d.sig, 3 << 22);
        assert_eq!(d.exp, -22);
        // Smallest subnormal = 2^-149.
        let d = decode(f, 1);
        assert_eq!(d.sig, 1);
        assert_eq!(d.exp, -149);
    }

    #[test]
    fn decode_encode_roundtrip_exhaustive_exponents() {
        // Every exponent with a few fraction patterns, both signs, every
        // supported format.
        for fmt in Format::all() {
            for e in 0..fmt.emax_biased() {
                for frac in [0u64, 1, fmt.frac_mask() / 2, fmt.frac_mask()] {
                    for sign in [false, true] {
                        let bits = (if sign { fmt.sign_bit() } else { 0 })
                            | (e << (fmt.sig_bits - 1))
                            | frac;
                        let d = decode(fmt, bits);
                        if d.class == Class::Zero {
                            assert_eq!(fmt.zero(d.sign), bits);
                            continue;
                        }
                        let back = encode_finite(fmt, d.sign, d.exp, d.sig);
                        assert_eq!(back, bits, "fmt={fmt:?} e={e} frac={frac:#x}");
                    }
                }
            }
        }
    }

    #[test]
    fn decoded_value_matches_f64_semantics() {
        // Rescale tiny values so 2^exp stays in f64's normal range (2^exp
        // for exp < -1022 would lose bits as a subnormal).
        for x in [1.0f64, -2.5, 6.02e23, 1e-250] {
            let d = decode(Format::DP, x.to_bits());
            let v = (d.sig as f64) * 2f64.powi(d.exp + 500) * if d.sign { -1.0 } else { 1.0 };
            assert_eq!(v, x * 2f64.powi(500));
        }
    }

    #[test]
    fn bitlen_helpers() {
        assert_eq!(bitlen64(0), 0);
        assert_eq!(bitlen64(1), 1);
        assert_eq!(bitlen64(u64::MAX), 64);
        assert_eq!(bitlen128(1u128 << 100), 101);
        assert_eq!(bitlen128(0), 0);
    }
}
