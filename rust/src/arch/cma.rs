//! The cascade multiply-add (CMA) datapath: a rounded multiplier feeding
//! a rounded adder (Fig. 1(b)) — the architecture of the paper's two
//! latency-optimized units.
//!
//! A CMA computes `round(round(a·b) + c)`: two IEEE-correct roundings.
//! Its total latency exceeds an FMA's, but the *accumulation* path —
//! result fed back to the adder input, the common case in SPEC FP
//! kernels — is only `add_pipe` cycles deep, because a dependent op
//! enters at the adder (stage `mul_pipe+1`), not at the multiplier. With
//! the internal before-rounding bypass (Fig. 2(a,b)), the unrounded sum
//! at the last add stage short-circuits the rounder as well. That is the
//! paper's Fig. 2(c) claim: 37%/57% lower average latency penalty than a
//! 5-cycle FMA with/without forwarding. Timing is modelled in
//! [`crate::pipesim`]; this module owns the numerics and activity.

use super::fp::Format;
use super::fma::FmaActivity;
use super::multiplier::{multiply_t, MultiplierConfig};
use super::rounding::{Flags, RoundMode, Rounded};
use super::softfloat::{self};
use super::fp::{decode, Class};

/// Static structural parameters of a CMA datapath.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CmaStructure {
    pub sig_bits: u32,
    /// Multiplier window (2m+2) and its own rounder.
    pub mul_window: u32,
    /// The separate adder datapath width (m+4: operand + guard/round/
    /// sticky + carry headroom) — far narrower than an FMA's 3m+5 merge.
    pub adder_width: u32,
    pub pp_count: u32,
    pub tree_levels: u32,
    /// The CMA carries two rounders (multiply and add).
    pub rounders: u32,
}

impl CmaStructure {
    /// Derive from the multiplier configuration.
    pub fn derive(mul: &MultiplierConfig) -> CmaStructure {
        let m = mul.sig_bits;
        CmaStructure {
            sig_bits: m,
            mul_window: mul.window(),
            adder_width: m + 4,
            pp_count: mul.pp_count(),
            tree_levels: mul.tree_depth(),
            rounders: 2,
        }
    }
}

/// Result of the cascaded operation with per-step flags (merged per IEEE
/// semantics of two distinct operations).
#[derive(Debug, Clone, Copy)]
pub struct CmaResult {
    /// Final rounded `round(round(a·b) + c)`.
    pub result: Rounded,
    /// The intermediate rounded product (what the bypass network forwards
    /// once rounded; the unrounded form exists one stage earlier).
    pub product: Rounded,
}

/// One cascade multiply-add: structural multiply, round, structural-width
/// add, round.
pub fn fmac(
    fmt: Format,
    mul_cfg: &MultiplierConfig,
    mode: RoundMode,
    a_bits: u64,
    b_bits: u64,
    c_bits: u64,
) -> (CmaResult, FmaActivity) {
    fmac_t::<true>(fmt, mul_cfg, mode, a_bits, b_bits, c_bits)
}

/// Cascade datapath generic over activity tracking.
#[inline(always)]
pub fn fmac_t<const TRACK: bool>(
    fmt: Format,
    mul_cfg: &MultiplierConfig,
    mode: RoundMode,
    a_bits: u64,
    b_bits: u64,
    c_bits: u64,
) -> (CmaResult, FmaActivity) {
    debug_assert_eq!(fmt.sig_bits, mul_cfg.sig_bits);
    let a = decode(fmt, a_bits);
    let b = decode(fmt, b_bits);

    let mut act = FmaActivity::default();
    let product = if a.class == Class::Normal && b.class == Class::Normal
        || a.class == Class::Subnormal && b.class == Class::Normal
        || a.class == Class::Normal && b.class == Class::Subnormal
        || a.class == Class::Subnormal && b.class == Class::Subnormal
    {
        // Structural multiplier on the finite path.
        let mr = multiply_t::<TRACK>(mul_cfg, a.sig, b.sig);
        if TRACK {
            act.digits = mr.pp_stats.digits;
            act.nonzero_digits = mr.pp_stats.nonzero_digits;
            act.tree_fa_ops = mr.tree_stats.fa_ops;
            act.tree_toggles = mr.tree_stats.toggles;
        }
        let exact = softfloat::Exact {
            sign: a.sign ^ b.sign,
            exp: a.exp + b.exp,
            sig: mr.product(mul_cfg),
            sticky: false,
        };
        let r = softfloat::round(fmt, mode, exact);
        debug_assert_eq!(r.bits, softfloat::mul(fmt, mode, a_bits, b_bits).bits);
        r
    } else {
        act.special = true;
        softfloat::mul(fmt, mode, a_bits, b_bits)
    };

    // Cascade into the adder (always IEEE-correct; the adder is the plain
    // m+4-bit FP adder with its own rounder).
    let sum = softfloat::add(fmt, mode, product.bits, c_bits);
    let result = Rounded { bits: sum.bits, flags: Flags::merge(product.flags, sum.flags) };
    (CmaResult { result, product }, act)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::booth::BoothRadix;
    use crate::arch::tree::TreeKind;

    fn sp_cma() -> MultiplierConfig {
        MultiplierConfig { sig_bits: 24, booth: BoothRadix::Booth2, tree: TreeKind::Wallace }
    }

    fn dp_cma() -> MultiplierConfig {
        MultiplierConfig { sig_bits: 53, booth: BoothRadix::Booth3, tree: TreeKind::Wallace }
    }

    fn cascade_ref32(a: f32, b: f32, c: f32) -> f32 {
        // Reference semantics: two correctly-rounded IEEE operations. Rust
        // f32 arithmetic is exactly that.
        a * b + c
    }

    #[test]
    fn matches_two_step_ieee_sp() {
        let cfg = sp_cma();
        let vals = [0.0f32, -0.0, 1.0, -1.5, 0.1, 3.0e20, 1e-30, f32::MAX, f32::MIN_POSITIVE,
                    2f32.powi(-140), f32::INFINITY, f32::NAN];
        for &a in &vals {
            for &b in &vals {
                for &c in &vals {
                    let (r, _) = fmac(Format::SP, &cfg, RoundMode::NearestEven,
                                      a.to_bits() as u64, b.to_bits() as u64, c.to_bits() as u64);
                    let got = f32::from_bits(r.result.bits as u32);
                    let want = cascade_ref32(a, b, c);
                    assert!(
                        (got.is_nan() && want.is_nan()) || got.to_bits() == want.to_bits(),
                        "cma({a:e},{b:e},{c:e}) = {got:e} want {want:e}"
                    );
                }
            }
        }
    }

    #[test]
    fn matches_two_step_ieee_dp() {
        let cfg = dp_cma();
        let vals = [0.0f64, 1.0, -1.0, 1e300, 1e-300, f64::MAX, 2f64.powi(-1074), 0.3];
        for &a in &vals {
            for &b in &vals {
                for &c in &vals {
                    let (r, _) = fmac(Format::DP, &cfg, RoundMode::NearestEven,
                                      a.to_bits(), b.to_bits(), c.to_bits());
                    let got = f64::from_bits(r.result.bits);
                    let want = a * b + c;
                    assert!(
                        (got.is_nan() && want.is_nan()) || got.to_bits() == want.to_bits(),
                        "cma({a:e},{b:e},{c:e}) = {got:e} want {want:e}"
                    );
                }
            }
        }
    }

    #[test]
    fn cma_differs_from_fma_on_double_rounding() {
        // The canonical discriminator (same case as the FMA test, inverted
        // expectation): (1+2^-12)² - (1+2^-11) = 2^-24 fused, 0 cascaded.
        let cfg = sp_cma();
        let a = 1.0f32 + 2f32.powi(-12);
        let c = -(1.0f32 + 2f32.powi(-11));
        let (r, _) = fmac(Format::SP, &cfg, RoundMode::NearestEven,
                          a.to_bits() as u64, a.to_bits() as u64, c.to_bits() as u64);
        assert_eq!(f32::from_bits(r.result.bits as u32), 0.0);
        assert_eq!(a.mul_add(a, c), 2f32.powi(-24)); // fused would differ
    }

    #[test]
    fn intermediate_product_exposed_for_bypass() {
        let cfg = sp_cma();
        let (r, _) = fmac(Format::SP, &cfg, RoundMode::NearestEven,
                          3.0f32.to_bits() as u64, 7.0f32.to_bits() as u64,
                          1.0f32.to_bits() as u64);
        assert_eq!(f32::from_bits(r.product.bits as u32), 21.0);
        assert_eq!(f32::from_bits(r.result.bits as u32), 22.0);
    }

    #[test]
    fn flags_merge_across_cascade() {
        let cfg = sp_cma();
        // Product overflows: overflow flag must survive the add.
        let (r, _) = fmac(Format::SP, &cfg, RoundMode::NearestEven,
                          f32::MAX.to_bits() as u64, 2.0f32.to_bits() as u64, 0);
        assert!(r.result.flags.overflow);
        assert_eq!(f32::from_bits(r.result.bits as u32), f32::INFINITY);
    }

    #[test]
    fn structure_narrow_adder() {
        // The CMA's adder is ~3× narrower than an FMA merge (m+4 vs 3m+5)
        // — the structural root of its lower per-stage delay.
        let s = CmaStructure::derive(&sp_cma());
        assert_eq!(s.adder_width, 28);
        assert_eq!(s.rounders, 2);
        let dp = CmaStructure::derive(&dp_cma());
        assert_eq!(dp.adder_width, 57);
        assert_eq!(dp.pp_count, 18);
    }

    #[test]
    fn subnormal_product_into_add() {
        let cfg = sp_cma();
        let a = f32::MIN_POSITIVE;
        let (r, _) = fmac(Format::SP, &cfg, RoundMode::NearestEven,
                          a.to_bits() as u64, 0.5f32.to_bits() as u64,
                          1.0f32.to_bits() as u64);
        assert_eq!(f32::from_bits(r.result.bits as u32), a * 0.5 + 1.0);
    }
}
