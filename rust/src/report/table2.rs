//! Table II — the SP FMA vs published-designs comparison after
//! feature-size + FO4 scaling.

use crate::arch::generator::{FpuConfig, FpuUnit};
use crate::energy::power::evaluate;
use crate::energy::scaling::PublishedDesign;
use crate::energy::tech::Technology;
use crate::timing::nominal_op;

use super::TextTable;

/// One comparison row.
#[derive(Debug, Clone)]
pub struct Table2Entry {
    pub name: String,
    pub gflops_mm2: f64,
    pub gflops_w: f64,
    /// The paper's published cell values (for the diff columns).
    pub paper_mm2: f64,
    pub paper_w: f64,
}

/// Compute the comparison: our modelled SP FMA at nominal, plus the four
/// competitors scaled to 28nm by the paper's rule.
pub fn compute() -> Vec<Table2Entry> {
    let tech = Technology::fdsoi28();
    let cfg = FpuConfig::sp_fma();
    let unit = FpuUnit::generate(&cfg);
    let eff = evaluate(&unit, &tech, nominal_op(&cfg), 1.0).expect("nominal");
    let mut rows = vec![Table2Entry {
        name: "SP FMA (FPMax)".into(),
        gflops_mm2: eff.gflops_per_mm2,
        gflops_w: eff.gflops_per_w,
        paper_mm2: 217.0,
        paper_w: 106.0,
    }];
    for (d, (_, p_mm2, p_w)) in PublishedDesign::table2_competitors()
        .iter()
        .zip(crate::energy::scaling::TABLE2_SCALED)
    {
        let s = d.scale_to(tech.feature_nm);
        rows.push(Table2Entry {
            name: d.name.to_string(),
            gflops_mm2: s.gflops_mm2,
            gflops_w: s.gflops_w,
            paper_mm2: p_mm2,
            paper_w: p_w,
        });
    }
    rows
}

/// Print the reproduced table.
pub fn print(rows: &[Table2Entry]) {
    println!("\nTABLE II — SP throughput comparison, scaled to 28nm (model vs paper)\n");
    let mut t = TextTable::new(vec![
        "FPU design",
        "GFLOPS/mm²",
        "(paper)",
        "GFLOPS/W",
        "(paper)",
    ]);
    for r in rows {
        t.row(vec![
            r.name.clone(),
            format!("{:.1}", r.gflops_mm2),
            format!("{:.1}", r.paper_mm2),
            format!("{:.1}", r.gflops_w),
            format!("{:.1}", r.paper_w),
        ]);
    }
    t.print();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::rel_diff;

    #[test]
    fn shape_of_comparison_holds() {
        let rows = compute();
        assert_eq!(rows.len(), 5);
        let fpmax = &rows[0];
        // FPMax wins energy efficiency against every competitor.
        for r in &rows[1..] {
            assert!(fpmax.gflops_w > r.gflops_w, "{} should lose on GFLOPS/W", r.name);
        }
        // CELL (scaled) keeps the raw area-efficiency crown.
        let cell = rows.iter().find(|r| r.name.contains("CELL")).unwrap();
        assert!(cell.gflops_mm2 > fpmax.gflops_mm2);
        // …but FPMax beats the other three on area efficiency too.
        for r in rows[1..].iter().filter(|r| !r.name.contains("CELL")) {
            assert!(fpmax.gflops_mm2 > r.gflops_mm2, "{}", r.name);
        }
    }

    #[test]
    fn competitor_cells_match_paper_exactly() {
        // The scaling rule must reproduce the published cells (they are
        // inverse-scaled; the identity is the audit).
        for r in &compute()[1..] {
            assert!(rel_diff(r.gflops_mm2, r.paper_mm2) < 1e-9, "{}", r.name);
            assert!(rel_diff(r.gflops_w, r.paper_w) < 1e-9, "{}", r.name);
        }
    }

    #[test]
    fn fpmax_cell_within_model_tolerance() {
        let rows = compute();
        assert!(rel_diff(rows[0].gflops_mm2, rows[0].paper_mm2) < 0.35);
        assert!(rel_diff(rows[0].gflops_w, rows[0].paper_w) < 0.35);
    }

    #[test]
    fn print_smoke() {
        print(&compute());
    }
}
