//! Fig. 3 — throughput tradeoffs for the SP and DP FMAs: energy/FLOP vs
//! GFLOPS/mm² under (a) the architecture sweep at 1 V, (b) V_DD scaling
//! of the fabricated design, (c) V_DD + body-bias.
//!
//! Headline points reproduced: SP FMA **289 GFLOPS/W @ 79 GFLOPS/mm²**
//! (low-energy) and **278 GFLOPS/mm² @ 60 GFLOPS/W** (high-perf); DP FMA
//! 117 GFLOPS/W @ 13 GFLOPS/mm² and 111 GFLOPS/mm² @ 20 GFLOPS/W; body
//! bias worth ~21% energy at constant area efficiency.

use crate::arch::fp::Precision;
use crate::arch::generator::{FpuConfig, FpuKind};
use crate::dse::pareto::frontier;
use crate::dse::sweep::{
    arch_sweep, default_vbb_grid, default_vdd_grid, voltage_bb_sweep, voltage_sweep, DsePoint,
};
use crate::energy::power::EfficiencyPoint;
use crate::energy::tech::{OperatingPoint, Technology};

use super::TextTable;

/// The three curve families for one precision.
#[derive(Debug, Clone)]
pub struct Fig3 {
    pub precision: Precision,
    /// (a) architecture sweep at 1 V, V_BB = 0 (triangle marks).
    pub arch_points: Vec<DsePoint>,
    /// Pareto frontier indices of `arch_points`.
    pub arch_frontier: Vec<usize>,
    /// (b) V_DD scaling of the fabricated FMA (white squares).
    pub vdd_curve: Vec<EfficiencyPoint>,
    /// (c) V_DD + body-bias curve.
    pub vdd_bb_curve: Vec<EfficiencyPoint>,
    /// Operating extremes on curve (c).
    pub low_energy: EfficiencyPoint,
    pub high_perf: EfficiencyPoint,
    /// Body-bias benefit at matched area efficiency (paper: ~21%).
    pub bb_energy_gain: f64,
}

/// Paper headline points: (precision, low-energy (GFLOPS/W, GFLOPS/mm²),
/// high-perf (GFLOPS/mm², GFLOPS/W)).
pub const PAPER_POINTS: [(&str, f64, f64, f64, f64); 2] = [
    ("SP", 289.0, 79.0, 278.0, 60.0),
    ("DP", 117.0, 13.0, 111.0, 20.0),
];

/// Compute the figure for one precision.
pub fn compute(precision: Precision) -> Fig3 {
    let tech = Technology::fdsoi28();
    let cfg = FpuConfig::fma_of(precision);
    let arch_points = arch_sweep(precision, FpuKind::Fma, &tech, OperatingPoint::new(1.0, 0.0));
    let arch_frontier = frontier(&arch_points);
    let vdds = default_vdd_grid();
    let vdd_curve = voltage_sweep(&cfg, &tech, &vdds, 0.0);
    let vdd_bb_curve = voltage_bb_sweep(&cfg, &tech, &vdds, &default_vbb_grid());

    // The paper's two "operating modes" are specific points on the curve,
    // not unconstrained optima: the low-energy mode still delivers a
    // stated compute density, the high-performance mode still meets a
    // stated efficiency. Evaluate our curve at the same constraints so
    // the comparison is point-to-point.
    // Only SP and DP were fabricated; a transprecision curve is
    // evaluated against the SP constraint point (its nearest silicon
    // anchor) purely to pick comparable operating modes.
    let paper = match precision {
        Precision::Double => PAPER_POINTS[1],
        _ => PAPER_POINTS[0],
    };
    let low_energy = *vdd_bb_curve
        .iter()
        .filter(|p| p.gflops_per_mm2 >= 0.85 * paper.2)
        .max_by(|a, b| a.gflops_per_w.partial_cmp(&b.gflops_per_w).unwrap())
        .or_else(|| {
            vdd_bb_curve.iter().max_by(|a, b| a.gflops_per_w.partial_cmp(&b.gflops_per_w).unwrap())
        })
        .expect("nonempty curve");
    let high_perf = *vdd_bb_curve
        .iter()
        .filter(|p| p.gflops_per_w >= 0.85 * paper.4)
        .max_by(|a, b| a.gflops_per_mm2.partial_cmp(&b.gflops_per_mm2).unwrap())
        .or_else(|| {
            vdd_bb_curve
                .iter()
                .max_by(|a, b| a.gflops_per_mm2.partial_cmp(&b.gflops_per_mm2).unwrap())
        })
        .expect("nonempty curve");

    // BB benefit: compare energy/FLOP at matched area efficiency between
    // the no-BB curve and the BB curve (constant-area-efficiency cut).
    let bb_energy_gain = matched_energy_gain(&vdd_curve, &vdd_bb_curve);

    Fig3 {
        precision,
        arch_points,
        arch_frontier,
        vdd_curve,
        vdd_bb_curve,
        low_energy,
        high_perf,
        bb_energy_gain,
    }
}

/// Mean fractional energy/FLOP reduction of curve B vs curve A at
/// matched GFLOPS/mm² (linear interpolation on A).
fn matched_energy_gain(a: &[EfficiencyPoint], b: &[EfficiencyPoint]) -> f64 {
    let interp = |curve: &[EfficiencyPoint], x: f64| -> Option<f64> {
        // curve is ordered by increasing vdd → increasing gflops/mm².
        for w in curve.windows(2) {
            let (x0, x1) = (w[0].gflops_per_mm2, w[1].gflops_per_mm2);
            if (x0..=x1).contains(&x) {
                let t = if x1 > x0 { (x - x0) / (x1 - x0) } else { 0.0 };
                return Some(w[0].pj_per_flop * (1.0 - t) + w[1].pj_per_flop * t);
            }
        }
        None
    };
    let mut gains = Vec::new();
    for p in b {
        if let Some(e_a) = interp(a, p.gflops_per_mm2) {
            gains.push(1.0 - p.pj_per_flop / e_a);
        }
    }
    if gains.is_empty() {
        0.0
    } else {
        gains.iter().sum::<f64>() / gains.len() as f64
    }
}

/// Print the curves and headline points.
pub fn print(f: &Fig3) {
    let which = match f.precision {
        Precision::Single => "SP",
        Precision::Double => "DP",
        _ => f.precision.name(),
    };
    println!("\nFIG 3 — {which} FMA throughput tradeoffs\n");
    println!("architecture sweep @1V: {} designs, {} on the Pareto frontier",
             f.arch_points.len(), f.arch_frontier.len());
    let mut t = TextTable::new(vec!["curve", "V_DD", "V_BB", "GFLOPS/mm²", "GFLOPS/W", "pJ/FLOP"]);
    for p in &f.vdd_curve {
        t.row(vec![
            "VDD only".to_string(),
            format!("{:.2}", p.op.vdd),
            format!("{:.1}", p.op.vbb),
            format!("{:.0}", p.gflops_per_mm2),
            format!("{:.0}", p.gflops_per_w),
            format!("{:.2}", p.pj_per_flop),
        ]);
    }
    for p in &f.vdd_bb_curve {
        t.row(vec![
            "VDD+BB".to_string(),
            format!("{:.2}", p.op.vdd),
            format!("{:.1}", p.op.vbb),
            format!("{:.0}", p.gflops_per_mm2),
            format!("{:.0}", p.gflops_per_w),
            format!("{:.2}", p.pj_per_flop),
        ]);
    }
    t.print();
    let paper = PAPER_POINTS.iter().find(|p| p.0 == which).unwrap();
    println!(
        "\nlow-energy point : {:.0} GFLOPS/W @ {:.0} GFLOPS/mm²  (paper: {} @ {})",
        f.low_energy.gflops_per_w, f.low_energy.gflops_per_mm2, paper.1, paper.2
    );
    println!(
        "high-perf point  : {:.0} GFLOPS/mm² @ {:.0} GFLOPS/W  (paper: {} @ {})",
        f.high_perf.gflops_per_mm2, f.high_perf.gflops_per_w, paper.3, paper.4
    );
    println!("body-bias energy gain at matched perf: {:.0}% (paper: ~21%)", f.bb_energy_gain * 100.0);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::rel_diff;

    #[test]
    fn sp_headline_points_within_band() {
        let f = compute(Precision::Single);
        // Low-energy point: 289 GFLOPS/W @ 79 GFLOPS/mm².
        assert!(rel_diff(f.low_energy.gflops_per_w, 289.0) < 0.35,
                "low-energy {:.0} GFLOPS/W", f.low_energy.gflops_per_w);
        assert!(rel_diff(f.low_energy.gflops_per_mm2, 79.0) < 0.60,
                "low-energy {:.0} GFLOPS/mm²", f.low_energy.gflops_per_mm2);
        // High-perf point: 278 GFLOPS/mm² @ 60 GFLOPS/W.
        assert!(rel_diff(f.high_perf.gflops_per_mm2, 278.0) < 0.35,
                "high-perf {:.0} GFLOPS/mm²", f.high_perf.gflops_per_mm2);
        assert!(rel_diff(f.high_perf.gflops_per_w, 60.0) < 0.60,
                "high-perf {:.0} GFLOPS/W", f.high_perf.gflops_per_w);
    }

    #[test]
    fn dp_headline_points_within_band() {
        let f = compute(Precision::Double);
        assert!(rel_diff(f.low_energy.gflops_per_w, 117.0) < 0.35,
                "low-energy {:.0} GFLOPS/W", f.low_energy.gflops_per_w);
        assert!(rel_diff(f.high_perf.gflops_per_mm2, 111.0) < 0.35,
                "high-perf {:.0} GFLOPS/mm²", f.high_perf.gflops_per_mm2);
    }

    #[test]
    fn bb_curve_dominates_vdd_only() {
        let f = compute(Precision::Single);
        assert!(f.bb_energy_gain > 0.05, "BB gain {:.2}", f.bb_energy_gain);
        assert!(f.bb_energy_gain < 0.45);
    }

    #[test]
    fn curves_span_the_tradeoff() {
        let f = compute(Precision::Single);
        let perf_span = f.vdd_bb_curve.last().unwrap().gflops_per_mm2
            / f.vdd_bb_curve.first().unwrap().gflops_per_mm2;
        assert!(perf_span > 3.0, "span {perf_span:.1}");
        // Energy at the ends exceeds the minimum (the U-shape of Fig. 3).
        let min_e = f.vdd_bb_curve.iter().map(|p| p.pj_per_flop).fold(f64::INFINITY, f64::min);
        assert!(f.vdd_bb_curve.last().unwrap().pj_per_flop > min_e);
    }

    #[test]
    fn fabricated_design_near_arch_frontier() {
        // The chip's SP FMA must sit on (or within a few %) of the swept
        // frontier — FPGen picked it for a reason.
        let f = compute(Precision::Single);
        let fab = FpuConfig::sp_fma();
        let fab_point = f
            .arch_points
            .iter()
            .find(|p| {
                p.config.stages == fab.stages && p.config.booth == fab.booth && p.config.tree == fab.tree
            })
            .expect("fabricated config swept");
        // Not dominated by more than 10% in energy at ≥ its perf.
        for &i in &f.arch_frontier {
            let fp = &f.arch_points[i];
            if fp.eff.gflops_per_mm2 >= fab_point.eff.gflops_per_mm2 {
                assert!(
                    fab_point.eff.pj_per_flop < fp.eff.pj_per_flop * 1.25,
                    "fabricated point badly dominated"
                );
                break;
            }
        }
    }

    #[test]
    fn print_smoke() {
        print(&compute(Precision::Single));
    }
}
