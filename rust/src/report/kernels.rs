//! Kernel-workload report: the repeat-buffer sequencer versus unrolled
//! issue, measured on the chip path.
//!
//! For each kernel × unit preset the runner executes both encodings of
//! the same [`KernelProgram`] through
//! [`crate::chip::FpMaxChip::run_traced`], diffs the result banks
//! bit-for-bit, and scores both activity traces with the body-bias
//! energy model at the unit's nominal operating point.
//! The row keeps the *raw* cycle/op counts next to every derived claim,
//! so the CI checker can re-derive the occupancy and speedup verdicts
//! instead of trusting them — the same activity-scaling story the paper
//! tells for the datapath, applied to the issue front-end.

use crate::bb::{run_energy_trace, BbPolicy};
use crate::chip::{RunStats, UnitSel, BANK_RESULT};
use crate::energy::tech::Technology;
use crate::report::TextTable;
use crate::workloads::kernels::{default_suite, KernelProgram};

/// One kernel × unit measurement; raw counts plus derived claims.
#[derive(Debug, Clone)]
pub struct KernelRow {
    pub kernel: String,
    pub unit: UnitSel,
    pub ops: u64,
    /// Whole-program cycles of the repeat-buffer encoding.
    pub repeat_cycles: u64,
    /// Ops issued from inside repeat windows (raw, for re-derivation).
    pub window_ops: u64,
    /// Cycles attributed to repeat windows (decode + issue + drain).
    pub window_cycles: u64,
    /// Whole-program cycles of the unrolled reference encoding.
    pub unrolled_cycles: u64,
    /// Result-bank words that differ between the two encodings.
    pub result_mismatches: u64,
    /// `window_ops / window_cycles` — the in-burst occupancy claim.
    pub occupancy_in_burst: f64,
    /// `unrolled_cycles / repeat_cycles` — the issue-rate claim.
    pub issue_speedup: f64,
    pub pj_per_op_repeat: f64,
    pub pj_per_op_unrolled: f64,
}

fn run_one(
    prog: &KernelProgram,
    words: &[u64],
    window_slots: u64,
) -> crate::Result<(RunStats, Vec<u64>, f64)> {
    let mut chip = prog.loaded_chip(words)?;
    let (stats, trace) = chip.run_traced(window_slots)?;
    anyhow::ensure!(
        stats.ops == prog.ops(),
        "{}: sequencer issued {} ops, kernel defines {}",
        prog.name,
        stats.ops,
        prog.ops()
    );
    let out = chip.jtag().read_bank(BANK_RESULT, prog.results_total())?;
    let unit = chip.unit(prog.unit);
    let op = crate::timing::nominal_op(&unit.config);
    let energy = run_energy_trace(unit, &Technology::fdsoi28(), op.vdd, BbPolicy::static_nominal(), &trace)
        .ok_or_else(|| anyhow::anyhow!("{}: nominal point not evaluable", prog.name))?;
    Ok((stats, out, energy.pj_per_op))
}

/// Execute both encodings of one kernel and assemble its row.
pub fn run_kernel(prog: &KernelProgram, window_slots: u64) -> crate::Result<KernelRow> {
    let (rep_stats, rep_out, rep_pj) = run_one(prog, &prog.repeat_words(), window_slots)?;
    let (unr_stats, unr_out, unr_pj) = run_one(prog, &prog.unrolled_words(), window_slots)?;
    let result_mismatches =
        rep_out.iter().zip(&unr_out).filter(|(a, b)| a != b).count() as u64;
    Ok(KernelRow {
        kernel: prog.name.clone(),
        unit: prog.unit,
        ops: prog.ops(),
        repeat_cycles: rep_stats.cycles,
        window_ops: rep_stats.repeat_ops,
        window_cycles: rep_stats.repeat_cycles,
        unrolled_cycles: unr_stats.cycles,
        result_mismatches,
        occupancy_in_burst: rep_stats.repeat_occupancy(),
        issue_speedup: unr_stats.cycles as f64 / rep_stats.cycles.max(1) as f64,
        pj_per_op_repeat: rep_pj,
        pj_per_op_unrolled: unr_pj,
    })
}

/// The default kernel suite on the requested unit presets.
pub fn run_suite(
    units: &[UnitSel],
    seed: u64,
    window_slots: u64,
) -> crate::Result<Vec<KernelRow>> {
    let mut rows = Vec::new();
    for &unit in units {
        for prog in default_suite(unit, seed) {
            rows.push(run_kernel(&prog, window_slots)?);
        }
    }
    Ok(rows)
}

/// Pretty table of the measured rows.
pub fn render(rows: &[KernelRow]) -> String {
    let mut t = TextTable::new(vec![
        "kernel",
        "unit",
        "ops",
        "rep cyc",
        "unr cyc",
        "occ(burst)",
        "speedup",
        "pJ/op rep",
        "pJ/op unr",
        "mismatch",
    ]);
    for r in rows {
        t.row(vec![
            r.kernel.clone(),
            r.unit.name().to_string(),
            r.ops.to_string(),
            r.repeat_cycles.to_string(),
            r.unrolled_cycles.to_string(),
            format!("{:.3}", r.occupancy_in_burst),
            format!("{:.2}x", r.issue_speedup),
            format!("{:.2}", r.pj_per_op_repeat),
            format!("{:.2}", r.pj_per_op_unrolled),
            r.result_mismatches.to_string(),
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_rows_are_internally_consistent() {
        let rows = run_suite(&[UnitSel::SpFma], 7, 256).unwrap();
        assert_eq!(rows.len(), 3);
        for r in &rows {
            assert_eq!(r.result_mismatches, 0, "{}", r.kernel);
            // Claims must re-derive from the raw counts (the CI checker
            // repeats exactly this arithmetic).
            let occ = r.window_ops as f64 / r.window_cycles as f64;
            assert!((occ - r.occupancy_in_burst).abs() < 1e-12, "{}", r.kernel);
            let spd = r.unrolled_cycles as f64 / r.repeat_cycles as f64;
            assert!((spd - r.issue_speedup).abs() < 1e-12, "{}", r.kernel);
            assert!(r.occupancy_in_burst >= 0.9, "{}: {}", r.kernel, r.occupancy_in_burst);
            assert!(r.issue_speedup >= 1.5, "{}: {}", r.kernel, r.issue_speedup);
            // Idle drain slots cost leakage: the unrolled trace can
            // never be cheaper per op.
            assert!(r.pj_per_op_repeat <= r.pj_per_op_unrolled, "{}", r.kernel);
        }
    }
}
