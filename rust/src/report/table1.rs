//! Table I — the per-unit performance summary.
//!
//! For each fabricated unit the model reports the same rows the paper
//! does: structural parameters straight from the generator, plus the
//! physical quantities (area, frequency, leakage, total power) at the
//! nominal operating point, the **normalized** efficiencies there, the
//! **max** efficiencies over the legal (V_DD, V_BB) window, and the
//! min/norm benchmarked delay over the SPEC-FP-like suite.

use crate::arch::generator::{FpuConfig, FpuUnit};
use crate::dse::sweep::{default_vbb_grid, default_vdd_grid};
use crate::energy::components::unit_cost;
use crate::energy::power::{evaluate, EfficiencyPoint};
use crate::energy::tech::{OperatingPoint, Technology};
use crate::pipesim::{simulate, LatencyModel};
use crate::timing::{nominal_op, timing};
use crate::workloads::specfp::Profile;

use super::TextTable;

/// One reproduced Table-I column.
#[derive(Debug, Clone)]
pub struct Table1Entry {
    pub name: String,
    pub config: FpuConfig,
    pub area_mm2: f64,
    pub vdd: f64,
    pub vbb: f64,
    pub freq_ghz: f64,
    pub leak_mw: f64,
    pub total_mw: f64,
    pub norm_area_eff: f64,
    pub norm_energy_eff: f64,
    pub max_area_eff: f64,
    pub max_energy_eff: f64,
    pub norm_delay_ns: f64,
    pub min_delay_ns: f64,
}

/// The paper's published values for the same cells (name, area, freq,
/// leak, total, norm/max area eff, norm/max energy eff, norm/min delay).
pub const PAPER: [(&str, f64, f64, f64, f64, f64, f64, f64, f64, f64, f64); 4] = [
    ("DP CMA", 0.032, 1.19, 8.4, 66.0, 74.6, 87.5, 36.0, 128.0, 1.39, 1.18),
    ("DP FMA", 0.024, 0.91, 3.8, 41.0, 74.6, 111.0, 43.7, 117.0, 2.79, 1.88),
    ("SP CMA", 0.018, 1.36, 3.3, 25.0, 151.0, 165.0, 110.0, 314.0, 1.42, 1.30),
    ("SP FMA", 0.0081, 0.91, 1.6, 17.0, 217.0, 278.0, 106.0, 289.0, 1.77, 1.39),
];

/// Average cycles per FLOP over the SPEC-FP-like suite (arithmetic mean
/// across profiles, as the paper averages its benchmarks).
pub fn avg_cycles_per_op(unit: &FpuUnit, ops_per_profile: usize, seed: u64) -> f64 {
    let lat = LatencyModel::of(unit);
    let suite = Profile::suite();
    let total: f64 = suite
        .iter()
        .map(|p| simulate(&lat, &p.generate(ops_per_profile, seed)).avg_cycles_per_op)
        .sum();
    total / suite.len() as f64
}

/// Best (max-energy-eff, max-area-eff, min-delay) over the legal
/// operating window.
fn scan_extremes(
    unit: &FpuUnit,
    tech: &Technology,
    cycles_per_op: f64,
) -> (f64, f64, f64) {
    let mut best_eeff = 0.0f64;
    let mut best_aeff = 0.0f64;
    let mut best_delay = f64::INFINITY;
    for &vdd in &default_vdd_grid() {
        for &vbb in &default_vbb_grid() {
            let op = OperatingPoint::new(vdd, vbb);
            if !tech.valid(op) {
                continue;
            }
            if let Some(p) = evaluate(unit, tech, op, 1.0) {
                best_eeff = best_eeff.max(p.gflops_per_w);
                best_aeff = best_aeff.max(p.gflops_per_mm2);
                let t = timing(&unit.config, tech, op).unwrap();
                best_delay = best_delay.min(t.cycle_ps * cycles_per_op / 1000.0);
            }
        }
    }
    (best_eeff, best_aeff, best_delay)
}

/// Compute all four Table-I columns.
pub fn compute() -> Vec<Table1Entry> {
    let tech = Technology::fdsoi28();
    FpuConfig::fpmax_units()
        .iter()
        .map(|cfg| {
            let unit = FpuUnit::generate(cfg);
            let op = nominal_op(cfg);
            let eff: EfficiencyPoint = evaluate(&unit, &tech, op, 1.0).expect("nominal operable");
            let cost = unit_cost(&unit);
            let cycles_per_op = avg_cycles_per_op(&unit, 20_000, 42);
            let (max_eeff, max_aeff, min_delay) = scan_extremes(&unit, &tech, cycles_per_op);
            let t = timing(cfg, &tech, op).unwrap();
            Table1Entry {
                name: cfg.name(),
                config: *cfg,
                area_mm2: cost.area_mm2,
                vdd: op.vdd,
                vbb: op.vbb,
                freq_ghz: eff.freq_ghz,
                leak_mw: eff.power.leakage_mw,
                total_mw: eff.power.total_mw(),
                norm_area_eff: eff.gflops_per_mm2,
                norm_energy_eff: eff.gflops_per_w,
                max_area_eff: max_aeff,
                max_energy_eff: max_eeff,
                norm_delay_ns: t.cycle_ps * cycles_per_op / 1000.0,
                min_delay_ns: min_delay,
            }
        })
        .collect()
}

/// Print the reproduced table next to the paper's values.
pub fn print(entries: &[Table1Entry]) {
    println!("\nTABLE I — performance summary (model vs silicon)\n");
    let mut t = TextTable::new(vec![
        "FPU", "Area mm² (paper)", "Stages", "Booth", "Tree", "V_DD", "V_BB",
        "f GHz (paper)", "Leak mW (paper)", "Total mW (paper)",
    ]);
    for (e, p) in entries.iter().zip(PAPER) {
        t.row(vec![
            e.name.clone(),
            format!("{:.4} ({})", e.area_mm2, p.1),
            e.config.stages.to_string(),
            e.config.booth.name().to_string(),
            e.config.tree.name().to_string(),
            format!("{:.1}V", e.vdd),
            format!("{:.1}V", e.vbb),
            format!("{:.2} ({})", e.freq_ghz, p.2),
            format!("{:.1} ({})", e.leak_mw, p.3),
            format!("{:.1} ({})", e.total_mw, p.4),
        ]);
    }
    t.print();
    let mut t = TextTable::new(vec![
        "FPU",
        "Norm GFLOPS/mm² (paper)",
        "Max GFLOPS/mm² (paper)",
        "Norm GFLOPS/W (paper)",
        "Max GFLOPS/W (paper)",
        "Norm delay ns (paper)",
        "Min delay ns (paper)",
    ]);
    for (e, p) in entries.iter().zip(PAPER) {
        t.row(vec![
            e.name.clone(),
            format!("{:.0} ({})", e.norm_area_eff, p.5),
            format!("{:.0} ({})", e.max_area_eff, p.6),
            format!("{:.0} ({})", e.norm_energy_eff, p.7),
            format!("{:.0} ({})", e.max_energy_eff, p.8),
            format!("{:.2} ({})", e.norm_delay_ns, p.9),
            format!("{:.2} ({})", e.min_delay_ns, p.10),
        ]);
    }
    println!();
    t.print();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::rel_diff;

    #[test]
    fn entries_track_paper_within_tolerance() {
        let entries = compute();
        assert_eq!(entries.len(), 4);
        for (e, p) in entries.iter().zip(PAPER) {
            assert_eq!(e.name, p.0);
            assert!(rel_diff(e.area_mm2, p.1) < 0.25, "{} area {:.4} vs {}", e.name, e.area_mm2, p.1);
            assert!(rel_diff(e.freq_ghz, p.2) < 0.15, "{} freq {:.2} vs {}", e.name, e.freq_ghz, p.2);
            assert!(rel_diff(e.total_mw, p.4) < 0.25, "{} power {:.1} vs {}", e.name, e.total_mw, p.4);
            assert!(
                rel_diff(e.norm_area_eff, p.5) < 0.35,
                "{} norm area eff {:.0} vs {}", e.name, e.norm_area_eff, p.5
            );
            assert!(
                rel_diff(e.norm_energy_eff, p.7) < 0.35,
                "{} norm energy eff {:.0} vs {}", e.name, e.norm_energy_eff, p.7
            );
        }
    }

    #[test]
    fn max_dominates_norm() {
        for e in compute() {
            assert!(e.max_area_eff >= e.norm_area_eff, "{}", e.name);
            assert!(e.max_energy_eff >= e.norm_energy_eff, "{}", e.name);
            assert!(e.min_delay_ns <= e.norm_delay_ns, "{}", e.name);
        }
    }

    #[test]
    fn latency_units_have_lower_benchmarked_delay() {
        // The point of the CMAs: DP CMA beats DP FMA, SP CMA beats SP FMA
        // on benchmarked delay (Table I bottom row ordering).
        let e = compute();
        let delay = |n: &str| e.iter().find(|x| x.name == n).unwrap().norm_delay_ns;
        assert!(delay("DP CMA") < delay("DP FMA"));
        assert!(delay("SP CMA") < delay("SP FMA"));
    }

    #[test]
    fn print_smoke() {
        print(&compute());
    }
}
