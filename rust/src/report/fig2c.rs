//! Fig. 2(c) — average latency penalty: DP CMA (with internal bypasses)
//! vs a 5-cycle FMA with and without unrounded-result forwarding, over
//! the SPEC-FP-like suite.
//!
//! Paper claim: the CMA achieves **37% / 57% less** average latency
//! penalty than the FMA with / without forwarding.

use crate::arch::generator::{FpuConfig, FpuUnit};
use crate::pipesim::{simulate, LatencyModel};
use crate::workloads::specfp::Profile;

use super::TextTable;

/// Per-profile penalties for the three compared designs.
#[derive(Debug, Clone)]
pub struct Fig2cRow {
    pub profile: &'static str,
    pub cma: f64,
    pub fma_fwd: f64,
    pub fma_nofwd: f64,
}

/// The aggregate comparison.
#[derive(Debug, Clone)]
pub struct Fig2c {
    pub rows: Vec<Fig2cRow>,
    /// Mean penalties across the suite.
    pub cma_mean: f64,
    pub fma_fwd_mean: f64,
    pub fma_nofwd_mean: f64,
    /// Fractional reductions (paper: 0.37 and 0.57).
    pub reduction_vs_fwd: f64,
    pub reduction_vs_nofwd: f64,
}

/// The three compared latency models (paper §FPU Architectures): our DP
/// CMA, and 5-cycle DP FMAs with/without forwarding.
pub fn comparison_units() -> (FpuUnit, FpuUnit, FpuUnit) {
    let cma = FpuUnit::generate(&FpuConfig::dp_cma());
    let mut fma5 = FpuConfig::dp_fma();
    fma5.stages = 5;
    let fma_fwd = FpuUnit::generate(&fma5);
    let mut fma5_nofwd = fma5;
    fma5_nofwd.forwarding = false;
    let fma_nofwd = FpuUnit::generate(&fma5_nofwd);
    (cma, fma_fwd, fma_nofwd)
}

/// Run the comparison over the suite.
pub fn compute(ops_per_profile: usize, seed: u64) -> Fig2c {
    let (cma, fma_fwd, fma_nofwd) = comparison_units();
    let (l_cma, l_fwd, l_nofwd) =
        (LatencyModel::of(&cma), LatencyModel::of(&fma_fwd), LatencyModel::of(&fma_nofwd));
    let mut rows = Vec::new();
    for p in Profile::suite() {
        let trace = p.generate(ops_per_profile, seed);
        rows.push(Fig2cRow {
            profile: p.name,
            cma: simulate(&l_cma, &trace).avg_penalty,
            fma_fwd: simulate(&l_fwd, &trace).avg_penalty,
            fma_nofwd: simulate(&l_nofwd, &trace).avg_penalty,
        });
    }
    let n = rows.len() as f64;
    let cma_mean = rows.iter().map(|r| r.cma).sum::<f64>() / n;
    let fma_fwd_mean = rows.iter().map(|r| r.fma_fwd).sum::<f64>() / n;
    let fma_nofwd_mean = rows.iter().map(|r| r.fma_nofwd).sum::<f64>() / n;
    Fig2c {
        rows,
        cma_mean,
        fma_fwd_mean,
        fma_nofwd_mean,
        reduction_vs_fwd: 1.0 - cma_mean / fma_fwd_mean,
        reduction_vs_nofwd: 1.0 - cma_mean / fma_nofwd_mean,
    }
}

/// Print per-profile penalties and the aggregate reductions.
pub fn print(f: &Fig2c) {
    println!("\nFIG 2(c) — average latency penalty (cycles), DP CMA vs 5-cycle FMA\n");
    let mut t = TextTable::new(vec!["benchmark", "CMA w/ bypass", "FMA w/ fwd", "FMA w/o fwd"]);
    for r in &f.rows {
        t.row(vec![
            r.profile.to_string(),
            format!("{:.3}", r.cma),
            format!("{:.3}", r.fma_fwd),
            format!("{:.3}", r.fma_nofwd),
        ]);
    }
    t.row(vec![
        "MEAN".to_string(),
        format!("{:.3}", f.cma_mean),
        format!("{:.3}", f.fma_fwd_mean),
        format!("{:.3}", f.fma_nofwd_mean),
    ]);
    t.print();
    println!(
        "\nCMA reduction vs FMA w/ forwarding : {:.0}%  (paper: 37%)",
        f.reduction_vs_fwd * 100.0
    );
    println!(
        "CMA reduction vs FMA w/o forwarding: {:.0}%  (paper: 57%)",
        f.reduction_vs_nofwd * 100.0
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reductions_match_paper_shape() {
        let f = compute(20_000, 42);
        // Paper: 37% and 57%; accept the band around them (the trace
        // generator is synthetic).
        assert!(
            (0.25..0.50).contains(&f.reduction_vs_fwd),
            "reduction vs fwd {:.2}", f.reduction_vs_fwd
        );
        assert!(
            (0.45..0.70).contains(&f.reduction_vs_nofwd),
            "reduction vs nofwd {:.2}", f.reduction_vs_nofwd
        );
        // Ordering is strict on every profile.
        for r in &f.rows {
            assert!(r.cma < r.fma_fwd, "{}", r.profile);
            assert!(r.fma_fwd < r.fma_nofwd, "{}", r.profile);
        }
    }

    #[test]
    fn deterministic() {
        let a = compute(5_000, 7);
        let b = compute(5_000, 7);
        assert_eq!(a.cma_mean, b.cma_mean);
    }

    #[test]
    fn accumulate_heavy_profiles_show_biggest_win() {
        let f = compute(20_000, 42);
        let nbody = f.rows.iter().find(|r| r.profile == "synth.nbody").unwrap();
        let horner = f.rows.iter().find(|r| r.profile == "synth.horner").unwrap();
        let win = |r: &Fig2cRow| 1.0 - r.cma / r.fma_fwd;
        assert!(
            win(nbody) > win(horner),
            "accumulation-heavy code must benefit more from the CMA"
        );
    }

    #[test]
    fn print_smoke() {
        print(&compute(2_000, 1));
    }
}
