//! Energy proportionality across the transprecision format fleet.
//!
//! The paper's Table I covers the four fabricated SP/DP units; this
//! emitter extends the same structural model down the format ladder
//! (FP16, bfloat16, FP8) and reports the pJ/op-vs-format curve at each
//! unit's nominal operating point. Everything is derived from the same
//! calibrated component model — no new fitted constants — so the curve
//! is a genuine prediction of how the generator's datapaths scale as
//! the significand and exponent buses narrow.

use crate::arch::fp::Precision;
use crate::arch::generator::{FpuConfig, FpuKind, FpuUnit};
use crate::energy::components::unit_cost;
use crate::energy::power::evaluate;
use crate::energy::tech::Technology;
use crate::timing::nominal_op;

use super::TextTable;

/// One (format, kind) point on the energy-proportionality curve.
#[derive(Debug, Clone)]
pub struct FormatPoint {
    pub precision: Precision,
    pub kind: FpuKind,
    /// Storage width in bits.
    pub width: u32,
    pub area_mm2: f64,
    pub vdd: f64,
    pub freq_ghz: f64,
    /// Dynamic + leakage energy per op at full utilization.
    pub pj_per_op: f64,
    pub gflops_per_w: f64,
    pub gflops_per_mm2: f64,
}

impl FormatPoint {
    /// The canonical preset name, e.g. `fp16_fma` (matches the CLI's
    /// `--unit` spelling).
    pub fn unit_name(&self) -> String {
        format!("{}_{}", self.precision.name(), self.kind.name().to_lowercase())
    }
}

/// Compute the curve: every format × both unit kinds, widest first
/// within each kind grouping (`Precision::ALL` order).
pub fn compute() -> Vec<FormatPoint> {
    let tech = Technology::fdsoi28();
    let mut out = Vec::new();
    for precision in Precision::ALL {
        for kind in [FpuKind::Fma, FpuKind::Cma] {
            let cfg = match kind {
                FpuKind::Fma => FpuConfig::fma_of(precision),
                FpuKind::Cma => FpuConfig::cma_of(precision),
            };
            let unit = FpuUnit::generate(&cfg);
            let op = nominal_op(&cfg);
            let eff = evaluate(&unit, &tech, op, 1.0).expect("nominal point operable");
            let cost = unit_cost(&unit);
            out.push(FormatPoint {
                precision,
                kind,
                width: precision.format().width(),
                area_mm2: cost.area_mm2,
                vdd: op.vdd,
                freq_ghz: eff.freq_ghz,
                // FMAC = 2 FLOPs: pJ/op is twice pJ/FLOP.
                pj_per_op: 2.0 * eff.pj_per_flop,
                gflops_per_w: eff.gflops_per_w,
                gflops_per_mm2: eff.gflops_per_mm2,
            });
        }
    }
    out
}

/// Print the curve as a table plus the headline proportionality ratios.
pub fn print(points: &[FormatPoint]) {
    println!("\nFORMAT FLEET — energy proportionality at nominal operating points\n");
    let mut t = TextTable::new(vec![
        "unit", "bits", "area mm²", "V_DD", "f GHz", "pJ/op", "GFLOPS/W", "GFLOPS/mm²",
    ]);
    for p in points {
        t.row(vec![
            p.unit_name(),
            p.width.to_string(),
            format!("{:.5}", p.area_mm2),
            format!("{:.1}", p.vdd),
            format!("{:.2}", p.freq_ghz),
            format!("{:.3}", p.pj_per_op),
            format!("{:.0}", p.gflops_per_w),
            format!("{:.0}", p.gflops_per_mm2),
        ]);
    }
    t.print();
    let pj = |prec: Precision, kind: FpuKind| {
        points
            .iter()
            .find(|p| p.precision == prec && p.kind == kind)
            .map(|p| p.pj_per_op)
            .unwrap_or(f64::NAN)
    };
    for kind in [FpuKind::Fma, FpuKind::Cma] {
        println!(
            "{}: DP/SP {:.1}×  SP/FP16 {:.1}×  FP16/FP8e4m3 {:.1}×",
            kind.name(),
            pj(Precision::Double, kind) / pj(Precision::Single, kind),
            pj(Precision::Single, kind) / pj(Precision::Half, kind),
            pj(Precision::Half, kind) / pj(Precision::Fp8E4M3, kind),
        );
    }
}

/// Render the curve as the `bench: "formats"`-style JSON fragment the
/// CI checker re-derives the proportionality verdict from.
pub fn render_json(points: &[FormatPoint]) -> String {
    let mut s = String::from("  \"energy_curve\": [\n");
    for (i, p) in points.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"unit\": \"{}\", \"format\": \"{}\", \"kind\": \"{}\", \"bits\": {}, \
             \"area_mm2\": {:.6}, \"vdd\": {:.2}, \"freq_ghz\": {:.4}, \"pj_per_op\": {:.6}, \
             \"gflops_per_w\": {:.2}, \"gflops_per_mm2\": {:.2}}}{}\n",
            p.unit_name(),
            p.precision.name(),
            p.kind.name(),
            p.width,
            p.area_mm2,
            p.vdd,
            p.freq_ghz,
            p.pj_per_op,
            p.gflops_per_w,
            p.gflops_per_mm2,
            if i + 1 == points.len() { "" } else { "," }
        ));
    }
    s.push_str("  ]");
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn curve_covers_every_format_and_kind() {
        let pts = compute();
        assert_eq!(pts.len(), Precision::ALL.len() * 2);
        for precision in Precision::ALL {
            for kind in [FpuKind::Fma, FpuKind::Cma] {
                let p = pts
                    .iter()
                    .find(|p| p.precision == precision && p.kind == kind)
                    .unwrap_or_else(|| panic!("missing {precision:?} {kind:?}"));
                assert!(p.pj_per_op.is_finite() && p.pj_per_op > 0.0, "{}", p.unit_name());
                assert!(p.area_mm2 > 0.0 && p.freq_ghz > 0.0, "{}", p.unit_name());
            }
        }
    }

    #[test]
    fn energy_scales_down_the_format_ladder() {
        // The proportionality property the fleet exists for: within a
        // kind, narrower formats cost strictly less energy per op (and
        // area), ordered DP > SP > {FP16, BF16} > {FP8e4m3, FP8e5m2}.
        let pts = compute();
        let get = |prec: Precision, kind: FpuKind| {
            pts.iter().find(|p| p.precision == prec && p.kind == kind).unwrap()
        };
        for kind in [FpuKind::Fma, FpuKind::Cma] {
            let dp = get(Precision::Double, kind);
            let sp = get(Precision::Single, kind);
            for half in [Precision::Half, Precision::Bfloat16] {
                let h = get(half, kind);
                assert!(sp.pj_per_op > h.pj_per_op, "SP vs {}", h.unit_name());
                assert!(sp.area_mm2 > h.area_mm2, "SP vs {}", h.unit_name());
                for fp8 in [Precision::Fp8E4M3, Precision::Fp8E5M2] {
                    let e = get(fp8, kind);
                    assert!(h.pj_per_op > e.pj_per_op, "{} vs {}", h.unit_name(), e.unit_name());
                    assert!(h.area_mm2 > e.area_mm2, "{} vs {}", h.unit_name(), e.unit_name());
                }
            }
            assert!(dp.pj_per_op > sp.pj_per_op, "{}", kind.name());
        }
    }

    #[test]
    fn json_fragment_lists_every_unit_once() {
        let pts = compute();
        let json = render_json(&pts);
        for p in &pts {
            assert_eq!(
                json.matches(&format!("\"unit\": \"{}\"", p.unit_name())).count(),
                1,
                "{}",
                p.unit_name()
            );
        }
        assert!(json.contains("\"pj_per_op\""));
    }

    #[test]
    fn print_smoke() {
        print(&compute());
    }
}
