//! Fig. 4 — latency tradeoffs for the SP and DP CMAs: energy/op vs
//! average benchmarked delay, at 100% utilization with and without body
//! bias, and at 10% utilization with statically-set vs dynamically
//! adaptive body bias.
//!
//! Paper claims reproduced: BB cuts power ~13% when heavily used; a
//! statically forward-biased unit at 10% utilization pays ~3× energy/op
//! (leakage-dominated), recovered to ~1.5× by adaptive BB.

use crate::arch::engine::{ActivityTrace, WordUnit};
use crate::arch::fp::Precision;
use crate::arch::generator::{FpuConfig, FpuUnit};
use crate::bb::controller::{run_energy, run_energy_trace, BbPolicy, BbRunEnergy};
use crate::dse::sweep::default_vdd_grid;
use crate::energy::tech::{OperatingPoint, Technology};
use crate::pipesim::{simulate, LatencyModel};
use crate::timing::timing;
use crate::workloads::specfp::Profile;
use crate::workloads::throughput::{OperandMix, OperandStream};
use crate::workloads::utilization::UtilizationProfile;

use super::TextTable;

/// One point on a Fig. 4 curve.
#[derive(Debug, Clone, Copy)]
pub struct Fig4Point {
    pub vdd: f64,
    pub vbb: f64,
    /// Average benchmarked delay in ns (cycle × avg cycles/FLOP).
    pub delay_ns: f64,
    /// Energy per op in pJ (at the curve's utilization/policy).
    pub pj_per_op: f64,
}

/// The four curves for one precision.
#[derive(Debug, Clone)]
pub struct Fig4 {
    pub precision: Precision,
    pub full_nobb: Vec<Fig4Point>,
    pub full_bb: Vec<Fig4Point>,
    pub low_static: Vec<Fig4Point>,
    pub low_adaptive: Vec<Fig4Point>,
    /// Power saving of BB at 100% utilization, at the matched-delay point
    /// (paper: ~13%).
    pub bb_power_saving: f64,
    /// Energy blow-up at 10% utilization, static BB, at the min-energy
    /// point of the 100% curve (paper: ~3×).
    pub static_blowup: f64,
    /// Same with adaptive BB (paper: ~1.5×).
    pub adaptive_blowup: f64,
}

/// Average cycles per op of this unit over the SPEC-FP-like suite.
fn cycles_per_op(unit: &FpuUnit) -> f64 {
    let lat = LatencyModel::of(unit);
    let suite = Profile::suite();
    suite
        .iter()
        .map(|p| simulate(&lat, &p.generate(20_000, 42)).avg_cycles_per_op)
        .sum::<f64>()
        / suite.len() as f64
}

/// Evaluate one curve: for each V_DD, energy/op from the supplied
/// accounting (profile- or trace-based) under the policy, delay from the
/// 100%-utilization timing.
fn curve_with(
    unit: &FpuUnit,
    tech: &Technology,
    cpo: f64,
    vbb_for_timing: f64,
    policy_of: impl Fn(f64) -> BbPolicy,
    energy_of: impl Fn(f64, BbPolicy) -> Option<BbRunEnergy>,
) -> Vec<Fig4Point> {
    let mut out = Vec::new();
    for &vdd in &default_vdd_grid() {
        let op = OperatingPoint::new(vdd, vbb_for_timing);
        let Some(t) = timing(&unit.config, tech, op) else { continue };
        let policy = policy_of(t.freq_ghz);
        let Some(e) = energy_of(vdd, policy) else { continue };
        out.push(Fig4Point {
            vdd,
            vbb: vbb_for_timing,
            delay_ns: t.cycle_ps * cpo / 1000.0,
            pj_per_op: e.pj_per_op,
        });
    }
    out
}

/// Profile-based curve (the synthetic Fig. 4 path).
fn curve(
    unit: &FpuUnit,
    tech: &Technology,
    cpo: f64,
    vbb_for_timing: f64,
    policy_of: impl Fn(f64) -> BbPolicy,
    profile_of: impl Fn() -> UtilizationProfile,
) -> Vec<Fig4Point> {
    curve_with(unit, tech, cpo, vbb_for_timing, policy_of, |vdd, policy| {
        run_energy(unit, tech, vdd, policy, &profile_of())
    })
}

/// The 10%-activity blow-ups at the min-energy point of the 100% BB
/// curve: (static_blowup, adaptive_blowup).
fn blowups_at_min_energy(
    full_bb: &[Fig4Point],
    low_static: &[Fig4Point],
    low_adaptive: &[Fig4Point],
) -> (f64, f64) {
    let idx_min = full_bb
        .iter()
        .enumerate()
        .min_by(|a, b| a.1.pj_per_op.partial_cmp(&b.1.pj_per_op).unwrap())
        .map(|(i, _)| i)
        .expect("the 100% BB curve has at least one operable point");
    let base = full_bb[idx_min].pj_per_op;
    let s = low_static[idx_min.min(low_static.len() - 1)].pj_per_op;
    let a = low_adaptive[idx_min.min(low_adaptive.len() - 1)].pj_per_op;
    (s / base, a / base)
}

/// Compute the figure for one precision.
pub fn compute(precision: Precision) -> Fig4 {
    let tech = Technology::fdsoi28();
    let cfg = FpuConfig::cma_of(precision);
    let unit = FpuUnit::generate(&cfg);
    let cpo = cycles_per_op(&unit);
    let total = 1_000_000;
    let burst = 10_000;

    let full = |_f: f64| BbPolicy::Static { vbb: 0.0 };
    let full_nobb = curve(&unit, &tech, cpo, 0.0, full, || UtilizationProfile::full(total));
    let full_bb = curve(
        &unit, &tech, cpo, Technology::NOMINAL_VBB,
        |_f| BbPolicy::static_nominal(),
        || UtilizationProfile::full(total),
    );
    let low_static = curve(
        &unit, &tech, cpo, Technology::NOMINAL_VBB,
        |_f| BbPolicy::static_nominal(),
        || UtilizationProfile::duty(0.1, burst, total),
    );
    let low_adaptive = curve(
        &unit, &tech, cpo, Technology::NOMINAL_VBB,
        BbPolicy::adaptive_nominal,
        || UtilizationProfile::duty(0.1, burst, total),
    );

    // BB saving at 100%: compare energy at matched delay. The BB curve
    // reaches any given delay at a lower V_DD; interpolate the no-BB
    // curve at the BB curve's delays.
    let bb_power_saving = matched_delay_gain(&full_nobb, &full_bb);

    // Blow-ups at the min-energy point of the full-utilization BB curve.
    let (static_blowup, adaptive_blowup) =
        blowups_at_min_energy(&full_bb, &low_static, &low_adaptive);

    Fig4 {
        precision,
        full_nobb,
        full_bb,
        low_static,
        low_adaptive,
        bb_power_saving,
        static_blowup,
        adaptive_blowup,
    }
}

/// The measured-trace variant of Fig. 4: the same four curves, but every
/// energy point comes from [`run_energy_trace`] over **measured**
/// time-resolved traces — real operands executed through the word-level
/// tier, woven into the figure's utilization schedules — instead of the
/// synthetic profile shim. Per-window measured activity scales the
/// dynamic term; idle windows drive the adaptive policy.
#[derive(Debug, Clone)]
pub struct Fig4Measured {
    pub precision: Precision,
    /// Trace window width in issue slots.
    pub window_slots: u64,
    /// Occupancy of the low-utilization measured trace (≈ 0.1).
    pub occupancy_low: f64,
    pub full_nobb: Vec<Fig4Point>,
    pub full_bb: Vec<Fig4Point>,
    pub low_static: Vec<Fig4Point>,
    pub low_adaptive: Vec<Fig4Point>,
    /// Matched-delay energy saving of BB at 100% activity (paper: ~20%
    /// power saving from biasing; model target ≥ 15%).
    pub bb_power_saving: f64,
    /// Blow-ups at the min-energy point of the 100% BB curve.
    pub static_blowup: f64,
    pub adaptive_blowup: f64,
    /// static / adaptive energy at 10% activity — the paper's "almost 2×"
    /// recovery (model target ≥ 1.8×).
    pub adaptive_recovery: f64,
}

/// Trace-based curve: energy/op of `trace` under `policy_of(freq)` at
/// each V_DD, delay from the 100%-utilization timing.
fn curve_trace(
    unit: &FpuUnit,
    tech: &Technology,
    cpo: f64,
    vbb_for_timing: f64,
    policy_of: impl Fn(f64) -> BbPolicy,
    trace: &ActivityTrace,
) -> Vec<Fig4Point> {
    curve_with(unit, tech, cpo, vbb_for_timing, policy_of, |vdd, policy| {
        run_energy_trace(unit, tech, vdd, policy, trace)
    })
}

/// Compute the measured-trace figure for one precision. `total` is the
/// schedule length in cycles (the 10% curves burst 10k cycles at a time,
/// as in [`compute`], so it should be a multiple of 100k; the default
/// CLI run uses 1M). The traces are executed **once** (word-level,
/// tracked, one op per active cycle) and reused across the whole V_DD
/// grid.
pub fn compute_measured(precision: Precision, window_slots: u64, total: u64) -> Fig4Measured {
    assert!(total >= 100_000, "need at least one 10%-duty period");
    let tech = Technology::fdsoi28();
    let cfg = FpuConfig::cma_of(precision);
    let unit = FpuUnit::generate(&cfg);
    let word = WordUnit::of(&unit);
    let cpo = cycles_per_op(&unit);
    let burst = 10_000;

    let mut stream = OperandStream::new(cfg.precision, OperandMix::Finite, 42);
    let full_trace = ActivityTrace::record_profile(
        &word,
        &UtilizationProfile::full(total),
        window_slots,
        &mut stream,
    );
    let low_trace = ActivityTrace::record_profile(
        &word,
        &UtilizationProfile::duty(0.1, burst, total),
        window_slots,
        &mut stream,
    );

    let full_nobb = curve_trace(
        &unit, &tech, cpo, 0.0,
        |_f| BbPolicy::Static { vbb: 0.0 },
        &full_trace,
    );
    let full_bb = curve_trace(
        &unit, &tech, cpo, Technology::NOMINAL_VBB,
        |_f| BbPolicy::static_nominal(),
        &full_trace,
    );
    let low_static = curve_trace(
        &unit, &tech, cpo, Technology::NOMINAL_VBB,
        |_f| BbPolicy::static_nominal(),
        &low_trace,
    );
    let low_adaptive = curve_trace(
        &unit, &tech, cpo, Technology::NOMINAL_VBB,
        BbPolicy::adaptive_nominal,
        &low_trace,
    );

    let bb_power_saving = matched_delay_gain(&full_nobb, &full_bb);
    let (static_blowup, adaptive_blowup) =
        blowups_at_min_energy(&full_bb, &low_static, &low_adaptive);

    Fig4Measured {
        precision,
        window_slots,
        occupancy_low: low_trace.occupancy(),
        full_nobb,
        full_bb,
        low_static,
        low_adaptive,
        bb_power_saving,
        static_blowup,
        adaptive_blowup,
        adaptive_recovery: static_blowup / adaptive_blowup,
    }
}

/// Print the measured-trace variant.
pub fn print_measured(f: &Fig4Measured) {
    let which = match f.precision {
        Precision::Single => "SP",
        Precision::Double => "DP",
        _ => f.precision.name(),
    };
    println!(
        "\nFIG 4 (measured traces) — {which} CMA, {}-slot windows, low-trace occupancy {:.1}%\n",
        f.window_slots,
        f.occupancy_low * 100.0
    );
    let mut t = TextTable::new(vec!["curve", "V_DD", "delay ns", "pJ/op"]);
    let mut dump = |name: &str, c: &[Fig4Point]| {
        for p in c {
            t.row(vec![
                name.to_string(),
                format!("{:.2}", p.vdd),
                format!("{:.2}", p.delay_ns),
                format!("{:.1}", p.pj_per_op),
            ]);
        }
    };
    dump("100% no-BB", &f.full_nobb);
    dump("100% BB", &f.full_bb);
    dump("10% static BB", &f.low_static);
    dump("10% adaptive BB", &f.low_adaptive);
    t.print();
    println!(
        "\nBB energy saving at 100% activity (matched delay): {:.0}% (target ≥15%)",
        f.bb_power_saving * 100.0
    );
    println!("10% activity, static BB blow-up   : {:.1}×", f.static_blowup);
    println!("10% activity, adaptive BB blow-up : {:.1}×", f.adaptive_blowup);
    println!(
        "adaptive recovery vs static forward bias: {:.1}× (target ≥1.8×)",
        f.adaptive_recovery
    );
}

/// Mean fractional energy reduction of curve B vs A at matched delay.
fn matched_delay_gain(a: &[Fig4Point], b: &[Fig4Point]) -> f64 {
    let interp = |curve: &[Fig4Point], x: f64| -> Option<f64> {
        for w in curve.windows(2) {
            // delay decreases with vdd: windows descend.
            let (x0, x1) = (w[0].delay_ns, w[1].delay_ns);
            let (lo, hi) = if x0 < x1 { (x0, x1) } else { (x1, x0) };
            if (lo..=hi).contains(&x) {
                let t = if hi > lo { (x - x0) / (x1 - x0) } else { 0.0 };
                return Some(w[0].pj_per_op * (1.0 - t) + w[1].pj_per_op * t);
            }
        }
        None
    };
    let mut gains = Vec::new();
    for p in b {
        if let Some(e_a) = interp(a, p.delay_ns) {
            gains.push(1.0 - p.pj_per_op / e_a);
        }
    }
    if gains.is_empty() {
        0.0
    } else {
        gains.iter().sum::<f64>() / gains.len() as f64
    }
}

/// Print the four curves and headline factors.
pub fn print(f: &Fig4) {
    let which = match f.precision {
        Precision::Single => "SP",
        Precision::Double => "DP",
        _ => f.precision.name(),
    };
    println!("\nFIG 4 — {which} CMA latency tradeoffs (energy/op vs benchmarked delay)\n");
    let mut t = TextTable::new(vec!["curve", "V_DD", "delay ns", "pJ/op"]);
    let mut dump = |name: &str, c: &[Fig4Point]| {
        for p in c {
            t.row(vec![
                name.to_string(),
                format!("{:.2}", p.vdd),
                format!("{:.2}", p.delay_ns),
                format!("{:.1}", p.pj_per_op),
            ]);
        }
    };
    dump("100% no-BB", &f.full_nobb);
    dump("100% BB", &f.full_bb);
    dump("10% static BB", &f.low_static);
    dump("10% adaptive BB", &f.low_adaptive);
    t.print();
    println!("\nBB power saving at 100% utilization: {:.0}% (paper: ~13%)", f.bb_power_saving * 100.0);
    println!("10% util, static BB energy blow-up : {:.1}× (paper: ~3×)", f.static_blowup);
    println!("10% util, adaptive BB blow-up      : {:.1}× (paper: ~1.5×)", f.adaptive_blowup);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sp_headline_factors() {
        // Paper: ~3× static, ~1.5× adaptive. Our leakage model (fitted to
        // the four Table-I points with forward bias) runs somewhat hotter
        // at the min-energy voltage, so the static band is wide; the
        // *qualitative* claim — static blows up severalfold, adaptive
        // recovers most of it — is asserted strictly.
        let f = compute(Precision::Single);
        assert!((0.05..0.30).contains(&f.bb_power_saving), "bb saving {:.2}", f.bb_power_saving);
        assert!((2.0..5.5).contains(&f.static_blowup), "static {:.2}", f.static_blowup);
        assert!((1.05..2.2).contains(&f.adaptive_blowup), "adaptive {:.2}", f.adaptive_blowup);
        assert!(
            f.adaptive_blowup < 0.6 * f.static_blowup,
            "adaptive must recover most of the static blow-up"
        );
    }

    #[test]
    fn dp_headline_factors() {
        let f = compute(Precision::Double);
        assert!((1.8..5.5).contains(&f.static_blowup), "static {:.2}", f.static_blowup);
        assert!(f.adaptive_blowup < f.static_blowup);
    }

    #[test]
    fn adaptive_curve_between_full_and_static() {
        let f = compute(Precision::Single);
        for ((s, a), b) in f.low_static.iter().zip(&f.low_adaptive).zip(&f.full_bb) {
            assert!(a.pj_per_op <= s.pj_per_op + 1e-9);
            assert!(a.pj_per_op >= b.pj_per_op - 1e-9);
        }
    }

    #[test]
    fn delay_monotone_in_vdd() {
        let f = compute(Precision::Single);
        for w in f.full_bb.windows(2) {
            assert!(w[1].delay_ns < w[0].delay_ns, "delay must fall as vdd rises");
        }
    }

    #[test]
    fn print_smoke() {
        print(&compute(Precision::Single));
    }

    #[test]
    fn measured_trace_reproduces_paper_trend_sp() {
        // The acceptance criterion of the time-resolved pipeline: on the
        // same workloads Fig. 4 uses, adaptive BB over *measured* traces
        // must show ≥15% energy/op saving at 100% activity (BB vs no-BB
        // at matched delay) and ≥1.8× recovery at 10% activity versus the
        // static forward-bias policy.
        let f = compute_measured(Precision::Single, 1_000, 200_000);
        assert!((f.occupancy_low - 0.1).abs() < 0.01, "occupancy {:.3}", f.occupancy_low);
        assert!(
            f.bb_power_saving >= 0.15,
            "measured BB saving at 100% activity: {:.3} < 0.15",
            f.bb_power_saving
        );
        assert!(
            f.adaptive_recovery >= 1.8,
            "measured adaptive recovery at 10% activity: {:.2}× < 1.8×",
            f.adaptive_recovery
        );
        // The same qualitative shape as the synthetic figure.
        assert!((2.0..6.0).contains(&f.static_blowup), "static {:.2}", f.static_blowup);
        assert!(f.adaptive_blowup < f.static_blowup);
        assert!(f.adaptive_blowup >= 1.0);
    }

    #[test]
    fn measured_trace_dp_recovers_too() {
        let f = compute_measured(Precision::Double, 1_000, 100_000);
        assert!(f.adaptive_recovery > 1.5, "{:.2}", f.adaptive_recovery);
        assert!(f.adaptive_blowup < f.static_blowup);
    }

    #[test]
    fn measured_curves_track_synthetic_curves() {
        // Measured traces differ from the shim only through the measured
        // activity scale of the dynamic term — each point must stay
        // within the scale clamp's reach of its synthetic twin.
        let syn = compute(Precision::Single);
        let mes = compute_measured(Precision::Single, 1_000, 200_000);
        for (s, m) in syn.low_adaptive.iter().zip(&mes.low_adaptive) {
            assert_eq!(s.vdd, m.vdd);
            let ratio = m.pj_per_op / s.pj_per_op;
            assert!((0.3..=2.5).contains(&ratio), "vdd {}: ratio {ratio}", s.vdd);
        }
    }

    #[test]
    fn print_measured_smoke() {
        print_measured(&compute_measured(Precision::Single, 1_000, 100_000));
    }
}
