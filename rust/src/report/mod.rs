//! Experiment emitters: one submodule per table/figure of the paper's
//! evaluation. Each computes structured results (consumed by the bench
//! harnesses and tests) and pretty-prints the same rows/series the
//! paper reports.

pub mod fig2c;
pub mod fig3;
pub mod fig4;
pub mod formats;
pub mod kernels;
pub mod table1;
pub mod table2;

/// Minimal fixed-width text table used by every emitter.
pub struct TextTable {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl TextTable {
    pub fn new<S: Into<String>>(header: Vec<S>) -> TextTable {
        TextTable { header: header.into_iter().map(Into::into).collect(), rows: Vec::new() }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.chars().count();
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                width[i] = width[i].max(c.chars().count());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = width[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = String::new();
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&"-".repeat(width.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = TextTable::new(vec!["name", "value"]);
        t.row(vec!["x", "1"]);
        t.row(vec!["longer", "2.5"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("name") && lines[0].contains("value"));
        assert_eq!(lines[2].len(), lines[3].len());
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn row_width_checked() {
        let mut t = TextTable::new(vec!["a", "b"]);
        t.row(vec!["only one"]);
    }
}
