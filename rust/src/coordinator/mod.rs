//! The verification coordinator: batches operand streams through the
//! bit-accurate Rust datapaths **and** the AOT-compiled JAX/Pallas
//! artifact, cross-checks every result, and aggregates activity.
//!
//! This closes the three-layer loop of the reproduction:
//!
//! ```text
//!   L1/L2 (build time)        L3 (run time, this module)
//!   pallas kernel ──aot──►  artifact ──PJRT──► result bits ─┐
//!                                                           ├─ compare
//!   FpuConfig ──generate──► FpuUnit ──datapath─► result bits┘
//! ```
//!
//! The Rust side is parallelized over worker threads (std::thread::scope
//! — the offline environment has no tokio; the workload is pure CPU
//! compute, so a scoped fork-join is the right shape anyway).

use std::time::Instant;

use crate::arch::fp::{decode, Class, Precision};
use crate::arch::generator::{FpuKind, FpuUnit};
use crate::arch::rounding::RoundMode;
use crate::arch::softfloat;
use crate::runtime::FmacArtifact;
use crate::workloads::throughput::OperandTriple;

/// One mismatch record (capped in the report).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Mismatch {
    pub index: usize,
    pub a: u64,
    pub b: u64,
    pub c: u64,
    pub got: u64,
    pub want: u64,
}

/// Outcome of one cross-checked batch.
#[derive(Debug, Clone)]
pub struct VerifyReport {
    pub ops: usize,
    /// Artifact (PJRT) vs golden softfloat fused-FMA.
    pub artifact_mismatches: Vec<Mismatch>,
    /// Rust datapath vs its own semantics (fused for FMA units, cascade
    /// for CMA units).
    pub datapath_mismatches: Vec<Mismatch>,
    /// Toggle count reported by the artifact (activity proxy).
    pub artifact_toggles: u64,
    /// Wall-clock seconds: Rust datapath pass / PJRT pass.
    pub rust_secs: f64,
    pub pjrt_secs: f64,
}

impl VerifyReport {
    pub fn clean(&self) -> bool {
        self.artifact_mismatches.is_empty() && self.datapath_mismatches.is_empty()
    }
}

/// NaN-insensitive bit comparison: any-NaN ≡ any-NaN (payloads differ
/// legitimately between implementations).
fn same_value(precision: Precision, x: u64, y: u64) -> bool {
    if x == y {
        return true;
    }
    let fmt = precision.format();
    decode(fmt, x).class == Class::Nan && decode(fmt, y).class == Class::Nan
}

const MISMATCH_CAP: usize = 16;

/// Run `triples` through the Rust datapath of `unit` and through the
/// PJRT `artifact`, cross-checking both against the golden softfloat.
pub fn verify_batch(
    unit: &FpuUnit,
    artifact: &FmacArtifact,
    triples: &[OperandTriple],
    workers: usize,
) -> crate::Result<VerifyReport> {
    anyhow::ensure!(
        artifact.precision == unit.config.precision,
        "artifact precision {:?} != unit {:?}",
        artifact.precision,
        unit.config.precision
    );
    let precision = unit.config.precision;
    let fmt = precision.format();
    let n = triples.len();
    let a: Vec<u64> = triples.iter().map(|t| t.a).collect();
    let b: Vec<u64> = triples.iter().map(|t| t.b).collect();
    let c: Vec<u64> = triples.iter().map(|t| t.c).collect();

    // --- PJRT pass -------------------------------------------------
    let t0 = Instant::now();
    let out = artifact.fmac(&a, &b, &c)?;
    let pjrt_secs = t0.elapsed().as_secs_f64();

    // --- Rust datapath pass (parallel fork-join) ---------------------
    let t1 = Instant::now();
    let workers = workers.max(1).min(n.max(1));
    let mut datapath = vec![0u64; n];
    let chunk = n.div_ceil(workers);
    std::thread::scope(|s| {
        for (i, slot) in datapath.chunks_mut(chunk).enumerate() {
            let (a, b, c) = (&a, &b, &c);
            s.spawn(move || {
                let base = i * chunk;
                for (j, out) in slot.iter_mut().enumerate() {
                    let k = base + j;
                    *out = unit.fmac(a[k], b[k], c[k]).bits;
                }
            });
        }
    });
    let rust_secs = t1.elapsed().as_secs_f64();

    // --- Cross-checks -------------------------------------------------
    let mut artifact_mismatches = Vec::new();
    let mut datapath_mismatches = Vec::new();
    for i in 0..n {
        // The artifact implements the fused op; golden = softfloat::fma.
        let fused = softfloat::fma(fmt, RoundMode::NearestEven, a[i], b[i], c[i]).bits;
        if !same_value(precision, out.bits[i], fused) && artifact_mismatches.len() < MISMATCH_CAP {
            artifact_mismatches.push(Mismatch {
                index: i,
                a: a[i],
                b: b[i],
                c: c[i],
                got: out.bits[i],
                want: fused,
            });
        }
        // The unit implements its own Table-I semantics.
        let unit_want = match unit.config.kind {
            FpuKind::Fma => fused,
            FpuKind::Cma => {
                let p = softfloat::mul(fmt, RoundMode::NearestEven, a[i], b[i]);
                softfloat::add(fmt, RoundMode::NearestEven, p.bits, c[i]).bits
            }
        };
        if !same_value(precision, datapath[i], unit_want)
            && datapath_mismatches.len() < MISMATCH_CAP
        {
            datapath_mismatches.push(Mismatch {
                index: i,
                a: a[i],
                b: b[i],
                c: c[i],
                got: datapath[i],
                want: unit_want,
            });
        }
    }

    Ok(VerifyReport {
        ops: n,
        artifact_mismatches,
        datapath_mismatches,
        artifact_toggles: out.toggles,
        rust_secs,
        pjrt_secs,
    })
}

/// Pure-Rust verification (no artifact): unit datapath vs golden
/// softfloat. Used where PJRT is unavailable and by the test suite.
pub fn verify_datapath_only(unit: &FpuUnit, triples: &[OperandTriple], workers: usize) -> VerifyReport {
    let precision = unit.config.precision;
    let fmt = precision.format();
    let n = triples.len();
    let t1 = Instant::now();
    let workers = workers.max(1).min(n.max(1));
    let chunk = n.div_ceil(workers);
    let mut mismatches: Vec<Vec<Mismatch>> = Vec::new();
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for (i, ts) in triples.chunks(chunk).enumerate() {
            handles.push(s.spawn(move || {
                let mut local = Vec::new();
                for (j, t) in ts.iter().enumerate() {
                    let got = unit.fmac(t.a, t.b, t.c).bits;
                    let want = match unit.config.kind {
                        FpuKind::Fma => {
                            softfloat::fma(fmt, RoundMode::NearestEven, t.a, t.b, t.c).bits
                        }
                        FpuKind::Cma => {
                            let p = softfloat::mul(fmt, RoundMode::NearestEven, t.a, t.b);
                            softfloat::add(fmt, RoundMode::NearestEven, p.bits, t.c).bits
                        }
                    };
                    if !same_value(precision, got, want) && local.len() < MISMATCH_CAP {
                        local.push(Mismatch { index: i * chunk + j, a: t.a, b: t.b, c: t.c, got, want });
                    }
                }
                local
            }));
        }
        for h in handles {
            mismatches.push(h.join().expect("worker panicked"));
        }
    });
    VerifyReport {
        ops: n,
        artifact_mismatches: Vec::new(),
        datapath_mismatches: mismatches.into_iter().flatten().take(MISMATCH_CAP).collect(),
        artifact_toggles: 0,
        rust_secs: t1.elapsed().as_secs_f64(),
        pjrt_secs: 0.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::generator::FpuConfig;
    use crate::workloads::throughput::{OperandMix, OperandStream};

    #[test]
    fn datapath_only_all_units_clean() {
        for cfg in FpuConfig::fpmax_units() {
            let unit = FpuUnit::generate(&cfg);
            let mut s = OperandStream::new(cfg.precision, OperandMix::Finite, 77);
            let triples = s.batch(4000);
            let r = verify_datapath_only(&unit, &triples, 4);
            assert!(r.datapath_mismatches.is_empty(), "{}: {:?}", cfg.name(), r.datapath_mismatches.first());
            assert_eq!(r.ops, 4000);
        }
    }

    #[test]
    fn datapath_handles_specials_cleanly() {
        let cfg = FpuConfig::sp_fma();
        let unit = FpuUnit::generate(&cfg);
        let mut s = OperandStream::new(cfg.precision, OperandMix::Anything, 13);
        let triples = s.batch(4000);
        let r = verify_datapath_only(&unit, &triples, 4);
        assert!(r.datapath_mismatches.is_empty(), "{:?}", r.datapath_mismatches.first());
    }

    #[test]
    fn worker_counts_agree() {
        let cfg = FpuConfig::dp_cma();
        let unit = FpuUnit::generate(&cfg);
        let mut s = OperandStream::new(cfg.precision, OperandMix::Finite, 5);
        let triples = s.batch(1003); // deliberately not divisible
        for workers in [1, 2, 3, 8, 64] {
            let r = verify_datapath_only(&unit, &triples, workers);
            assert_eq!(r.ops, 1003);
            assert!(r.datapath_mismatches.is_empty(), "workers={workers}");
        }
    }

    #[test]
    fn same_value_nan_insensitive() {
        let qnan = 0x7fc0_0000u64;
        let other_nan = 0x7fc0_0001u64;
        assert!(same_value(Precision::Single, qnan, other_nan));
        assert!(!same_value(Precision::Single, qnan, 0x7f80_0000));
        assert!(same_value(Precision::Single, 5, 5));
    }
}
