//! The verification coordinator: batches operand streams through the
//! bit-accurate Rust datapaths **and** the AOT-compiled JAX/Pallas
//! artifact, cross-checks every result, and aggregates activity.
//!
//! This closes the three-layer loop of the reproduction:
//!
//! ```text
//!   L1/L2 (build time)        L3 (run time, this module)
//!   pallas kernel ──aot──►  artifact ──PJRT──► result bits ─┐
//!                                                           ├─ compare
//!   FpuConfig ──generate──► FpuUnit ──engine──► result bits┘
//! ```
//!
//! All Rust-side execution goes through the unified
//! [`crate::arch::engine::BatchExecutor`] — the coordinator no longer
//! carries a private worker loop. The gate-level datapath is the device
//! under test; its spec is the word-level tier of the same unit
//! (Table-I semantics), and the PJRT artifact is checked against the
//! fused golden softfloat ([`GoldenFma`]).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::arch::engine::{ActivityTrace, BatchExecutor, Fidelity, GoldenFma, UnitDatapath};
use crate::arch::fp::{decode, Class, Precision};
use crate::arch::generator::{FpuKind, FpuUnit};
use crate::runtime::chaos::{
    fnv1a_fold, ChaosReport, FaultKind, FaultPlan, FaultTrigger, ProducerStats, FNV_OFFSET,
};
use crate::runtime::router::{
    FleetReport, RetryPolicy, RoutePolicy, RouterConfig, ServeRouter, ShardHealth, ShardSpec,
    WorkloadClass,
};
use crate::runtime::serve::{ServeConfig, ServeError, ServeLoad, ServeQueue, ServeReport, Ticket};
use crate::runtime::trace::Trace;
use crate::runtime::FmacArtifact;
use crate::workloads::throughput::{OperandBatch, OperandMix, OperandStream, OperandTriple};

/// One mismatch record (capped in the report).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Mismatch {
    pub index: usize,
    pub a: u64,
    pub b: u64,
    pub c: u64,
    pub got: u64,
    pub want: u64,
}

/// Outcome of one cross-checked batch.
#[derive(Debug, Clone)]
pub struct VerifyReport {
    pub ops: usize,
    /// Artifact (PJRT) vs golden softfloat fused-FMA.
    pub artifact_mismatches: Vec<Mismatch>,
    /// Rust datapath vs its own semantics (fused for FMA units, cascade
    /// for CMA units).
    pub datapath_mismatches: Vec<Mismatch>,
    /// Toggle count reported by the artifact (activity proxy).
    pub artifact_toggles: u64,
    /// Wall-clock seconds: Rust datapath pass / PJRT pass.
    pub rust_secs: f64,
    pub pjrt_secs: f64,
}

impl VerifyReport {
    pub fn clean(&self) -> bool {
        self.artifact_mismatches.is_empty() && self.datapath_mismatches.is_empty()
    }
}

/// NaN-insensitive bit comparison: any-NaN ≡ any-NaN (payloads differ
/// legitimately between implementations).
fn same_value(precision: Precision, x: u64, y: u64) -> bool {
    if x == y {
        return true;
    }
    let fmt = precision.format();
    decode(fmt, x).class == Class::Nan && decode(fmt, y).class == Class::Nan
}

const MISMATCH_CAP: usize = 16;

/// Scan two result streams for disagreements, capped.
fn collect_mismatches(
    precision: Precision,
    triples: &[OperandTriple],
    got: &[u64],
    want: &[u64],
) -> Vec<Mismatch> {
    let mut out = Vec::new();
    for (i, t) in triples.iter().enumerate() {
        if !same_value(precision, got[i], want[i]) {
            out.push(Mismatch { index: i, a: t.a, b: t.b, c: t.c, got: got[i], want: want[i] });
            if out.len() >= MISMATCH_CAP {
                break;
            }
        }
    }
    out
}

/// Run `triples` through the Rust datapath of `unit` and through the
/// PJRT `artifact`, cross-checking both against the golden softfloat.
pub fn verify_batch(
    unit: &FpuUnit,
    artifact: &FmacArtifact,
    triples: &[OperandTriple],
    workers: usize,
) -> crate::Result<VerifyReport> {
    anyhow::ensure!(
        artifact.precision == unit.config.precision,
        "artifact precision {:?} != unit {:?}",
        artifact.precision,
        unit.config.precision
    );
    let precision = unit.config.precision;
    let soa = OperandBatch::from_triples(triples);

    // --- PJRT pass -------------------------------------------------
    let t0 = Instant::now();
    let out = artifact.fmac(&soa.a, &soa.b, &soa.c)?;
    let pjrt_secs = t0.elapsed().as_secs_f64();

    // --- Rust passes through the engine -------------------------------
    // Two reusable buffers cover all three passes: the fused golden
    // results are compared against the artifact first, then (for CMA
    // units) the same buffer is overwritten with the cascade reference —
    // the engine's `run_into` path allocates nothing further.
    let exec = BatchExecutor::new(workers);
    let n = triples.len();
    let mut datapath = vec![0u64; n];
    let mut reference = vec![0u64; n];
    let t1 = Instant::now();
    exec.run_into(unit, triples, &mut datapath)?;
    let rust_secs = t1.elapsed().as_secs_f64();
    // The chunk hint is now tuned for the ~10× slower gate-level pass;
    // retime it for the word-tier reference passes below.
    exec.recalibrate();
    exec.run_into(&GoldenFma { format: precision.format() }, triples, &mut reference)?;
    let artifact_mismatches = collect_mismatches(precision, triples, &out.bits, &reference);
    // CMA units are specified by the cascade; FMA units by the fused
    // golden results already in hand.
    if unit.config.kind == FpuKind::Cma {
        exec.run_into(&UnitDatapath::new(unit, Fidelity::WordSimd), triples, &mut reference)?;
    }

    Ok(VerifyReport {
        ops: n,
        artifact_mismatches,
        datapath_mismatches: collect_mismatches(precision, triples, &datapath, &reference),
        artifact_toggles: out.toggles,
        rust_secs,
        pjrt_secs,
    })
}

/// Pure-Rust verification (no artifact): the gate-level datapath against
/// its word-level spec, both driven by the shared executor. Used where
/// PJRT is unavailable and by the test suite.
pub fn verify_datapath_only(
    unit: &FpuUnit,
    triples: &[OperandTriple],
    workers: usize,
) -> VerifyReport {
    let exec = BatchExecutor::new(workers);
    let mut got = vec![0u64; triples.len()];
    let t1 = Instant::now();
    exec.run_into(unit, triples, &mut got).expect("buffers sized together");
    let rust_secs = t1.elapsed().as_secs_f64();
    datapath_report(unit, &exec, triples, &got, rust_secs)
}

/// Traced verification: like [`verify_datapath_only`], but the pass under
/// test runs **windowed-tracked** at the chosen fidelity tier, emitting
/// the time-resolved [`ActivityTrace`] the body-bias controller consumes.
/// The reference pass stays on the lane-batched word tier.
pub fn verify_datapath_traced(
    unit: &FpuUnit,
    tier: Fidelity,
    triples: &[OperandTriple],
    workers: usize,
    window_ops: usize,
) -> (VerifyReport, ActivityTrace) {
    let exec = BatchExecutor::new(workers);
    let mut got = vec![0u64; triples.len()];
    let dp = UnitDatapath::new(unit, tier);
    let t1 = Instant::now();
    let trace = exec
        .run_windowed_into(&dp, triples, &mut got, window_ops)
        .expect("buffers sized together");
    let rust_secs = t1.elapsed().as_secs_f64();
    (datapath_report(unit, &exec, triples, &got, rust_secs), trace)
}

/// Shared tail of the datapath verifications: retune the chunk hint (the
/// timed pass calibrated it on a different tier's per-op cost), run the
/// lane-batched word reference — same bits, none of the scalar decode
/// tax — and assemble the report.
fn datapath_report(
    unit: &FpuUnit,
    exec: &BatchExecutor,
    triples: &[OperandTriple],
    got: &[u64],
    rust_secs: f64,
) -> VerifyReport {
    let mut want = vec![0u64; triples.len()];
    exec.recalibrate();
    exec.run_into(&UnitDatapath::new(unit, Fidelity::WordSimd), triples, &mut want)
        .expect("buffers sized together");
    VerifyReport {
        ops: triples.len(),
        artifact_mismatches: Vec::new(),
        datapath_mismatches: collect_mismatches(unit.config.precision, triples, got, &want),
        artifact_toggles: 0,
        rust_secs,
        pjrt_secs: 0.0,
    }
}

/// Drive `unit` through the streaming serve layer: `load.producers`
/// threads submit `load.total_ops` ops at `tier` in variable-sized
/// chunks (idle phases woven in under `load.duty`), the queue coalesces
/// them into batches over the persistent pool's stealing scheduler, and
/// the streaming body-bias controller re-biases mid-run off the window
/// ring. Every producer validates its returned result lengths; the
/// returned [`ServeReport`] carries sustained throughput, submission
/// latency percentiles, the sampled gate cross-check, and the
/// streamed-vs-post-hoc bias-schedule comparison.
pub fn serve_datapath(
    unit: &FpuUnit,
    tier: Fidelity,
    load: ServeLoad,
    cfg: ServeConfig,
) -> crate::Result<ServeReport> {
    anyhow::ensure!(load.producers >= 1, "need at least one producer");
    anyhow::ensure!(load.sub_ops >= 1, "submissions need at least one op");
    anyhow::ensure!(
        load.duty > 0.0 && load.duty <= 1.0,
        "--duty must be in (0, 1], got {}",
        load.duty
    );
    let queue = ServeQueue::start(unit, cfg)?;
    let max_q = queue.max_queue_ops();
    let precision = unit.config.precision;
    let produced = std::thread::scope(|s| -> crate::Result<()> {
        let mut joins = Vec::new();
        for p in 0..load.producers {
            let handle = queue.handle();
            let share = load.total_ops / load.producers
                + usize::from(p < load.total_ops % load.producers);
            joins.push(s.spawn(move || -> crate::Result<()> {
                drive_producer(
                    precision,
                    share,
                    load.sub_ops,
                    load.duty,
                    producer_seeds(load.seed, p),
                    |triples| handle.submit(tier, triples, max_q),
                    |slots| handle.submit_idle(slots),
                )
            }));
        }
        let mut first_err = None;
        for j in joins {
            match j.join().map_err(|_| anyhow::anyhow!("serve producer panicked")) {
                Ok(Ok(())) => {}
                Ok(Err(e)) | Err(e) => {
                    first_err.get_or_insert(e);
                }
            }
        }
        first_err.map_or(Ok(()), Err)
    });
    // Finish even when a producer failed: finish() closes the queue and
    // joins the dispatcher/controller — bailing first would leak them.
    let finished = queue.finish();
    match produced {
        Ok(()) => finished,
        Err(e) => Err(e),
    }
}

/// The deterministic per-producer seed pair every synthetic serve
/// workload uses: (operand-stream seed, submission-size seed).
fn producer_seeds(seed: u64, p: usize) -> (u64, u64) {
    (
        seed.wrapping_add(0x9E37_79B9_7F4A_7C15u64.wrapping_mul(p as u64 + 1)),
        seed ^ (((p as u64 + 1) << 32) | 0xA5),
    )
}

/// One synthetic serve producer: submits `share` ops of `precision` in
/// variable-sized chunks around `sub_ops`, keeps a bounded ticket
/// pipeline in flight (validating every returned result length), and
/// weaves idle-slot submissions in to hit `duty` occupancy. Shared by
/// the single-queue ([`serve_datapath`]) and routed ([`serve_routed`])
/// workloads — only the submission target differs.
fn drive_producer<FS, FI>(
    precision: Precision,
    share: usize,
    sub_ops: usize,
    duty: f64,
    (stream_seed, size_seed): (u64, u64),
    mut submit: FS,
    mut submit_idle: FI,
) -> crate::Result<()>
where
    FS: FnMut(Vec<OperandTriple>) -> crate::Result<Ticket>,
    FI: FnMut(u64) -> crate::Result<()>,
{
    /// Submissions a producer keeps in flight before waiting the oldest.
    const INFLIGHT: usize = 8;
    /// Bursts between idle-phase submissions (batching the idle debt
    /// keeps gaps long enough for the settle-time rule to act on).
    const BURSTS_PER_IDLE: u64 = 4;

    let mut stream = OperandStream::new(precision, OperandMix::Finite, stream_seed);
    let mut rng = crate::util::Rng::new(size_seed);
    let mut left = share;
    let mut inflight: std::collections::VecDeque<(usize, Ticket)> =
        std::collections::VecDeque::new();
    let mut ops_since_idle = 0u64;
    let mut idle_debt = 0.0f64;
    while left > 0 {
        let span =
            (sub_ops / 2 + rng.below(sub_ops.max(1) as u64) as usize).clamp(1, left);
        let triples = stream.batch(span);
        inflight.push_back((span, submit(triples)?));
        if inflight.len() > INFLIGHT {
            let (m, t) = inflight.pop_front().expect("nonempty");
            let bits = t.wait()?;
            anyhow::ensure!(bits.len() == m, "short result: {} of {m}", bits.len());
        }
        left -= span;
        ops_since_idle += span as u64;
        if duty < 1.0 && ops_since_idle >= BURSTS_PER_IDLE * sub_ops as u64 {
            idle_debt += ops_since_idle as f64 * (1.0 - duty) / duty;
            ops_since_idle = 0;
            let slots = idle_debt as u64;
            if slots > 0 {
                submit_idle(slots)?;
                idle_debt -= slots as f64;
            }
        }
    }
    if duty < 1.0 && ops_since_idle > 0 {
        let slots = (idle_debt + ops_since_idle as f64 * (1.0 - duty) / duty) as u64;
        if slots > 0 {
            submit_idle(slots)?;
        }
    }
    for (m, t) in inflight {
        let bits = t.wait()?;
        anyhow::ensure!(bits.len() == m, "short result: {} of {m}", bits.len());
    }
    Ok(())
}

/// A synthetic routed serving workload for [`serve_routed`]:
/// `producers_per_class` producer threads **per workload class** (all
/// four of [`WorkloadClass::ALL`] — mixed SP/DP, latency/bulk) submit
/// `total_ops` ops in variable-sized chunks through the router, idle
/// phases woven in under `duty`.
#[derive(Debug, Clone, Copy)]
pub struct RoutedLoad {
    /// Total ops across all producers of all classes.
    pub total_ops: usize,
    /// Producer threads per workload class (4 classes ⇒ `4 × this`
    /// threads).
    pub producers_per_class: usize,
    /// Mean submission size; actual sizes vary in `[sub_ops/2, 3·sub_ops/2)`.
    pub sub_ops: usize,
    /// Target occupancy in `(0, 1]` per class's affinity shard.
    pub duty: f64,
    /// Operand/size stream seed.
    pub seed: u64,
}

/// Drive a shard fleet through the [`ServeRouter`]: mixed SP/DP
/// latency/bulk producers submit classified work, the router dispatches
/// by Table-1 unit affinity (spilling under backlog pressure when the
/// config allows), and every shard's streaming body-bias controller
/// re-biases its own unit mid-run. Every producer validates its
/// returned result lengths; the returned [`FleetReport`] carries the
/// per-shard serve reports (each holding the single-shard bit-identity
/// gates), the per-class shard histogram, and the merged fleet
/// accounting.
pub fn serve_routed(
    specs: &[ShardSpec],
    rcfg: RouterConfig,
    tier: Fidelity,
    load: RoutedLoad,
) -> crate::Result<FleetReport> {
    anyhow::ensure!(load.producers_per_class >= 1, "need at least one producer per class");
    anyhow::ensure!(load.sub_ops >= 1, "submissions need at least one op");
    anyhow::ensure!(
        load.duty > 0.0 && load.duty <= 1.0,
        "--duty must be in (0, 1], got {}",
        load.duty
    );
    let router = ServeRouter::start(specs, rcfg)?;
    let classes = WorkloadClass::ALL;
    let producers = classes.len() * load.producers_per_class;
    let produced = std::thread::scope(|s| -> crate::Result<()> {
        let mut joins = Vec::new();
        for p in 0..producers {
            let class = classes[p % classes.len()];
            let share =
                load.total_ops / producers + usize::from(p < load.total_ops % producers);
            let router = &router;
            joins.push(s.spawn(move || -> crate::Result<()> {
                drive_producer(
                    class.precision,
                    share,
                    load.sub_ops,
                    load.duty,
                    producer_seeds(load.seed, p),
                    |triples| router.submit(class, tier, triples).map(|(_, t)| t),
                    |slots| router.submit_idle(class, tier, slots).map(|_| ()),
                )
            }));
        }
        let mut first_err = None;
        for j in joins {
            match j.join().map_err(|_| anyhow::anyhow!("routed serve producer panicked")) {
                Ok(Ok(())) => {}
                Ok(Err(e)) | Err(e) => {
                    first_err.get_or_insert(e);
                }
            }
        }
        first_err.map_or(Ok(()), Err)
    });
    // Finish the fleet even when a producer failed: router.finish()
    // closes every shard queue and joins its threads — bailing first
    // would leak all of them. The producer error still wins the report.
    let finished = router.finish();
    match produced {
        Ok(()) => finished,
        Err(e) => Err(e),
    }
}

/// Outcome of a chaos run: the gated report plus the full fleet detail
/// behind it.
#[derive(Debug, Clone)]
pub struct ChaosOutcome {
    pub report: ChaosReport,
    pub fleet: FleetReport,
}

/// Drive the routed fleet under a seeded [`FaultPlan`]: producers per
/// workload class submit through the resilient path
/// ([`ServeRouter::submit_with_retry`], deadline-bounded waits, capped
/// exponential backoff on retryable faults) while an injector thread
/// arms each scheduled fault when the fleet-wide submitted-op counter
/// crosses its trigger point. The supervisor respawns killed shards
/// mid-run; the returned [`ChaosReport`] holds the producer-side
/// submission ledger and the hard gates.
///
/// Determinism note: the *plan* is fully determined by its seed, and so
/// are the operand/size streams (same seeds as [`serve_routed`]). With
/// an empty plan the run is a plain routed run — same streams, same
/// affinity placement, same result bits (witnessed by the per-producer
/// checksums in the report). `load.duty` is ignored: chaos producers
/// weave no idle phases — duty-cycle shaping is [`serve_routed`]'s
/// experiment, failure-handling is this one's.
pub fn serve_chaos(
    specs: &[ShardSpec],
    rcfg: RouterConfig,
    tier: Fidelity,
    load: RoutedLoad,
    plan: &FaultPlan,
    deadline: Duration,
    retry: RetryPolicy,
) -> crate::Result<ChaosOutcome> {
    anyhow::ensure!(load.producers_per_class >= 1, "need at least one producer per class");
    anyhow::ensure!(load.sub_ops >= 1, "submissions need at least one op");
    for f in &plan.faults {
        let shard_ok = match f.kind {
            FaultKind::KillDispatcher { shard }
            | FaultKind::WorkerPanic { shard }
            | FaultKind::RingFlood { shard, .. }
            | FaultKind::Latency { shard, .. } => shard < specs.len(),
            FaultKind::NanStorm { class_idx, .. } => class_idx < WorkloadClass::ALL.len(),
        };
        anyhow::ensure!(shard_ok, "fault {:?} targets outside the fleet", f.kind);
    }
    anyhow::ensure!(
        !plan.needs_replay_clock(),
        "fault plan has trace-slot triggers; only serve_trace advances a replay clock"
    );
    let t0 = Instant::now();
    let router = ServeRouter::start(specs, rcfg)?;
    let classes = WorkloadClass::ALL;
    let producers = classes.len() * load.producers_per_class;
    let submitted_ops = AtomicU64::new(0);
    let done = AtomicBool::new(false);
    let (fired, stats, produce_err) = std::thread::scope(|s| {
        let injector = s.spawn(|| {
            let mut fired = Vec::new();
            for f in &plan.faults {
                // Op-anchored only: the replay-clock plans were
                // rejected at entry.
                let FaultTrigger::SubmittedOps(at) = f.trigger else {
                    unreachable!("trace-slot plans are rejected before producers start")
                };
                while submitted_ops.load(Ordering::Relaxed) < at
                    && !done.load(Ordering::Relaxed)
                {
                    std::thread::sleep(Duration::from_micros(200));
                }
                // A fault aimed at a shard that is itself mid-respawn
                // can bounce off a closed queue — retry the injection
                // briefly rather than dropping plan coverage.
                let armed = Instant::now();
                loop {
                    if fire_fault(&router, tier, f.kind, deadline).is_ok() {
                        fired.push(f.kind);
                        break;
                    }
                    if armed.elapsed() > Duration::from_secs(5) {
                        break;
                    }
                    std::thread::sleep(Duration::from_millis(5));
                }
            }
            fired
        });
        let mut joins = Vec::new();
        for p in 0..producers {
            let class = classes[p % classes.len()];
            let share =
                load.total_ops / producers + usize::from(p < load.total_ops % producers);
            let router = &router;
            let submitted_ops = &submitted_ops;
            joins.push(s.spawn(move || {
                chaos_producer(
                    router,
                    class,
                    tier,
                    share,
                    load.sub_ops,
                    producer_seeds(load.seed, p),
                    deadline,
                    retry,
                    submitted_ops,
                )
            }));
        }
        let mut stats = ProducerStats::default();
        let mut err: Option<anyhow::Error> = None;
        for j in joins {
            match j.join() {
                Ok(Ok(p)) => stats.absorb(&p),
                Ok(Err(e)) => {
                    err.get_or_insert(e);
                }
                Err(_) => {
                    err.get_or_insert(anyhow::anyhow!("chaos producer panicked"));
                }
            }
        }
        done.store(true, Ordering::Relaxed);
        let fired = injector.join().unwrap_or_default();
        (fired, stats, err)
    });
    // Let in-flight recoveries land before teardown: a kill fired near
    // the end of the stream may still be mid-respawn, and finish() on a
    // half-booted shard is an error, not an accounting merge.
    let recovery_grace = Instant::now() + Duration::from_secs(30);
    while Instant::now() < recovery_grace {
        let healthy = (0..router.shard_count())
            .all(|i| router.shard_health(i) == ShardHealth::Healthy);
        if healthy {
            break;
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    let finished = router.finish();
    if let Some(e) = produce_err {
        return Err(e);
    }
    let fleet = finished?;
    let report = ChaosReport::new(
        plan.seed,
        tier.name(),
        plan,
        &fired,
        stats,
        &fleet,
        t0.elapsed().as_secs_f64(),
    );
    Ok(ChaosOutcome { report, fleet })
}

/// Arm one fault against the live fleet.
fn fire_fault(
    router: &ServeRouter,
    tier: Fidelity,
    kind: FaultKind,
    deadline: Duration,
) -> crate::Result<()> {
    match kind {
        FaultKind::KillDispatcher { shard } => router.shard_handle(shard).inject_fault(),
        FaultKind::WorkerPanic { shard } => router.shard_handle(shard).inject_worker_panic(),
        FaultKind::RingFlood { shard, windows } => {
            // Idle slots arrive in one submission but publish one window
            // per `window_ops` — a burst the controller can't drain in
            // step, forcing the ring's coalescing path.
            let slots = windows.saturating_mul(router.shard_window_ops(shard) as u64);
            router.shard_handle(shard).submit_idle(slots)
        }
        FaultKind::Latency { shard, micros } => {
            router.shard_handle(shard).inject_latency(Duration::from_micros(micros))
        }
        FaultKind::NanStorm { class_idx, ops } => {
            let class = WorkloadClass::ALL[class_idx % WorkloadClass::ALL.len()];
            let triples =
                OperandStream::new(class.precision, OperandMix::SpecialHeavy, 0x5707_11 ^ ops as u64)
                    .batch(ops.max(1));
            // Routed like any traffic; the storm's results are surviving
            // work, so they flow through the sampled cross-check too.
            let outcome = router.submit_with_retry(
                class,
                tier,
                &triples,
                Some(deadline),
                RetryPolicy::bounded(4, Duration::from_millis(1), Duration::from_millis(50)),
            )?;
            anyhow::ensure!(
                outcome.bits.len() == triples.len(),
                "NaN storm came back short: {} of {}",
                outcome.bits.len(),
                triples.len()
            );
            Ok(())
        }
    }
}

/// One chaos producer: same operand/size streams as
/// [`drive_producer`], but every submission goes through the resilient
/// deadline + retry path, and every outcome lands in exactly one
/// column of the [`ProducerStats`] ledger. Returns `Err` only for
/// harness-level corruption (a *short* successful result) — fleet
/// faults are data, not errors, in a chaos run.
#[allow(clippy::too_many_arguments)]
fn chaos_producer(
    router: &ServeRouter,
    class: WorkloadClass,
    tier: Fidelity,
    share: usize,
    sub_ops: usize,
    (stream_seed, size_seed): (u64, u64),
    deadline: Duration,
    retry: RetryPolicy,
    submitted_ops: &AtomicU64,
) -> crate::Result<ProducerStats> {
    let mut stream = OperandStream::new(class.precision, OperandMix::Finite, stream_seed);
    let mut rng = crate::util::Rng::new(size_seed);
    let mut st = ProducerStats::default();
    let mut checksum = FNV_OFFSET;
    let mut left = share;
    while left > 0 {
        let span =
            (sub_ops / 2 + rng.below(sub_ops.max(1) as u64) as usize).clamp(1, left);
        let triples = stream.batch(span);
        st.submitted_subs += 1;
        st.submitted_ops += span as u64;
        submitted_ops.fetch_add(span as u64, Ordering::Relaxed);
        // Backoff jitter derives from the submission's own identity
        // (size-stream seed × submission index), never the wall clock —
        // a replayed run reproduces its retry timing decisions.
        let retry_seed = size_seed ^ st.submitted_subs.rotate_left(20);
        match router.submit_with_retry_seeded(class, tier, &triples, Some(deadline), retry, retry_seed)
        {
            Ok(out) => {
                anyhow::ensure!(
                    out.bits.len() == span,
                    "short result: {} of {span}",
                    out.bits.len()
                );
                for b in &out.bits {
                    checksum = fnv1a_fold(checksum, *b);
                }
                st.completed_subs += 1;
                st.completed_ops += span as u64;
                st.retries += u64::from(out.retries);
            }
            Err(e) => {
                if ServeError::classify(&e) == Some(ServeError::DeadlineExceeded) {
                    st.hung_subs += 1;
                    st.hung_ops += span as u64;
                } else {
                    st.errored_subs += 1;
                    st.errored_ops += span as u64;
                }
            }
        }
        left -= span;
    }
    st.checksums.push(checksum);
    Ok(st)
}

/// Issue-slot equivalents per virtual trace slot: the scale that turns
/// a tenant's inter-arrival gap into idle accounting on the fleet, so
/// the BB controllers see the trace's duty cycle, not just its work.
const IDLE_OPS_PER_SLOT: u64 = 32;

/// Outcome of one trace replay: the digest-bearing report plus the
/// full fleet detail behind it.
#[derive(Debug, Clone)]
pub struct ReplayOutcome {
    pub report: ReplayReport,
    pub fleet: FleetReport,
}

/// What a replayed trace produced, split into two kinds of numbers:
///
/// * **Deterministic invariants** — the trace fingerprint, per-class op
///   totals, and the producer ledger (and, under kind-preserving
///   configurations, the per-tenant result checksums). These fold into
///   [`ReplayReport::digest`]: same seed + same trace ⇒ bit-identical
///   digest, the replay determinism gate.
/// * **Measurements** — sustained throughput, fleet pJ/op, placement
///   counters, wall time. Timing-dependent by nature (routing under
///   load observes live pressure and feedback); these are what the
///   static-vs-dynamic dominance verdict reads, and they are *excluded*
///   from the digest.
#[derive(Debug, Clone)]
pub struct ReplayReport {
    pub seed: u64,
    pub tier_name: &'static str,
    pub policy_name: &'static str,
    /// [`Trace::fingerprint`] — identity of the replayed input.
    pub trace_fingerprint: u64,
    pub events: usize,
    pub tenants: usize,
    /// The replay clock's final value.
    pub last_slot: u64,
    /// Per-class submitted ops in [`WorkloadClass::index`] order.
    pub class_ops: [u64; WorkloadClass::COUNT],
    pub producer: ProducerStats,
    pub faults_planned: usize,
    pub faults_fired: usize,
    /// Fleet placement counters (see [`FleetReport`]).
    pub misrouted: u64,
    pub policy_routed: u64,
    pub rerouted_on_failure: u64,
    pub admission_denied: u64,
    pub respawns: u64,
    pub fleet_ops: u64,
    pub crosscheck_sampled: u64,
    pub crosscheck_mismatches: u64,
    pub fleet_pj_per_op: f64,
    /// Completed ops over end-to-end wall time — the throughput number
    /// the dominance verdict compares.
    pub sustained_ops_per_s: f64,
    pub conservation_ok: bool,
    /// Whether the per-tenant result checksums were folded into the
    /// digest (kind-preserving policy + spill disabled + no faults;
    /// cross-kind placement legitimately changes result bits, so a
    /// dynamic run's digest covers the ledger invariants only).
    pub results_in_digest: bool,
    pub digest: u64,
    pub wall_secs: f64,
}

impl ReplayReport {
    /// Gate: every submission resolved within its deadline.
    pub fn zero_hung(&self) -> bool {
        self.producer.hung_subs == 0 && self.producer.hung_ops == 0
    }

    /// Gate: completed + errored + hung == submitted on both ledgers.
    pub fn zero_lost(&self) -> bool {
        self.producer.completed_subs + self.producer.errored_subs + self.producer.hung_subs
            == self.producer.submitted_subs
            && self.producer.completed_ops + self.producer.errored_ops + self.producer.hung_ops
                == self.producer.submitted_ops
    }

    /// Gate: every planned fault fired.
    pub fn coverage_ok(&self) -> bool {
        self.faults_fired == self.faults_planned
    }

    /// Gate: zero sampled cross-check mismatches.
    pub fn crosscheck_clean(&self) -> bool {
        self.crosscheck_mismatches == 0
    }

    /// All hard gates (incl. [`FleetReport::conservation_ok`], captured
    /// at construction).
    pub fn gates_ok(&self) -> bool {
        self.zero_hung()
            && self.zero_lost()
            && self.coverage_ok()
            && self.crosscheck_clean()
            && self.conservation_ok
    }
}

/// The replay digest: FNV-1a over the deterministic invariants only.
/// `retries` and every wall-clock measurement stay out — they are
/// timing, not identity.
fn replay_digest(
    trace_fingerprint: u64,
    class_ops: &[u64; WorkloadClass::COUNT],
    p: &ProducerStats,
    results_in_digest: bool,
) -> u64 {
    let mut h = fnv1a_fold(FNV_OFFSET, trace_fingerprint);
    for &c in class_ops {
        h = fnv1a_fold(h, c);
    }
    for v in [
        p.submitted_subs,
        p.completed_subs,
        p.errored_subs,
        p.hung_subs,
        p.submitted_ops,
        p.completed_ops,
        p.errored_ops,
        p.hung_ops,
    ] {
        h = fnv1a_fold(h, v);
    }
    h = fnv1a_fold(h, results_in_digest as u64);
    if results_in_digest {
        for &c in &p.checksums {
            h = fnv1a_fold(h, c);
        }
    }
    h
}

/// Replay a seeded multi-tenant [`Trace`] against a shard fleet under a
/// chosen [`RoutePolicy`] — the experiment that judges the dynamic
/// policies against the static baseline on realistic load shapes.
///
/// One producer thread per tenant walks its slice of the event stream
/// in virtual-time order: each event's inter-arrival gap becomes idle
/// accounting ([`ServeRouter::submit_idle`], [`IDLE_OPS_PER_SLOT`]
/// issue slots per trace slot), then its ops are submitted through the
/// resilient seeded-retry path ([`ServeRouter::submit_with_retry_seeded`]
/// — backoff jitter derives from the event's own `op_seed`, never the
/// wall clock). The shared replay clock is the monotonic max of
/// submitted event slots; an injector thread fires the plan's faults
/// against whichever axis each trigger names, so slot-anchored chaos
/// ([`FaultTrigger::TraceSlot`]) composes with the trace's duty cycle.
pub fn serve_trace(
    specs: &[ShardSpec],
    rcfg: RouterConfig,
    tier: Fidelity,
    trace: &Trace,
    policy: Arc<dyn RoutePolicy>,
    plan: &FaultPlan,
    deadline: Duration,
    retry: RetryPolicy,
) -> crate::Result<ReplayOutcome> {
    anyhow::ensure!(!trace.events.is_empty(), "trace has no events");
    for f in &plan.faults {
        let shard_ok = match f.kind {
            FaultKind::KillDispatcher { shard }
            | FaultKind::WorkerPanic { shard }
            | FaultKind::RingFlood { shard, .. }
            | FaultKind::Latency { shard, .. } => shard < specs.len(),
            FaultKind::NanStorm { class_idx, .. } => class_idx < WorkloadClass::ALL.len(),
        };
        anyhow::ensure!(shard_ok, "fault {:?} targets outside the fleet", f.kind);
    }
    let results_in_digest = policy.kind_preserving()
        && rcfg.spill_pressure_ops == usize::MAX
        && plan.faults.is_empty();
    let t0 = Instant::now();
    let router = ServeRouter::start_with_policy(specs, rcfg, policy)?;
    let tenants = trace.config.tenants;
    let mut per_tenant: Vec<Vec<crate::runtime::trace::TraceEvent>> = vec![Vec::new(); tenants];
    for e in &trace.events {
        // The global stream is (slot, tenant)-sorted, so each tenant's
        // slice stays in its own arrival order.
        per_tenant[e.tenant].push(*e);
    }
    let submitted_ops = AtomicU64::new(0);
    let replay_slot = AtomicU64::new(0);
    let done = AtomicBool::new(false);
    let (fired, stats, produce_err) = std::thread::scope(|s| {
        let injector = s.spawn(|| {
            let mut fired = Vec::new();
            for f in &plan.faults {
                loop {
                    let reached = match f.trigger {
                        FaultTrigger::SubmittedOps(at) => {
                            submitted_ops.load(Ordering::Relaxed) >= at
                        }
                        FaultTrigger::TraceSlot(at) => replay_slot.load(Ordering::Relaxed) >= at,
                    };
                    if reached || done.load(Ordering::Relaxed) {
                        break;
                    }
                    std::thread::sleep(Duration::from_micros(200));
                }
                let armed = Instant::now();
                loop {
                    if fire_fault(&router, tier, f.kind, deadline).is_ok() {
                        fired.push(f.kind);
                        break;
                    }
                    if armed.elapsed() > Duration::from_secs(5) {
                        break;
                    }
                    std::thread::sleep(Duration::from_millis(5));
                }
            }
            fired
        });
        let mut joins = Vec::new();
        for events in &per_tenant {
            let router = &router;
            let submitted_ops = &submitted_ops;
            let replay_slot = &replay_slot;
            joins.push(s.spawn(move || {
                trace_tenant(router, tier, events, deadline, retry, submitted_ops, replay_slot)
            }));
        }
        let mut stats = ProducerStats::default();
        let mut err: Option<anyhow::Error> = None;
        for j in joins {
            match j.join() {
                Ok(Ok(p)) => stats.absorb(&p),
                Ok(Err(e)) => {
                    err.get_or_insert(e);
                }
                Err(_) => {
                    err.get_or_insert(anyhow::anyhow!("trace tenant panicked"));
                }
            }
        }
        done.store(true, Ordering::Relaxed);
        let fired = injector.join().unwrap_or_default();
        (fired, stats, err)
    });
    // Same recovery grace as the chaos harness: a kill fired near the
    // tail may still be mid-respawn, and finish() on a half-booted
    // shard is an error, not an accounting merge.
    if !plan.faults.is_empty() {
        let recovery_grace = Instant::now() + Duration::from_secs(30);
        while Instant::now() < recovery_grace {
            let healthy = (0..router.shard_count())
                .all(|i| router.shard_health(i) == ShardHealth::Healthy);
            if healthy {
                break;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
    }
    let finished = router.finish();
    if let Some(e) = produce_err {
        return Err(e);
    }
    let fleet = finished?;
    let wall_secs = t0.elapsed().as_secs_f64();
    let class_ops = trace.class_ops();
    let digest = replay_digest(trace.fingerprint, &class_ops, &stats, results_in_digest);
    let report = ReplayReport {
        seed: trace.config.seed,
        tier_name: tier.name(),
        policy_name: fleet.policy_name,
        trace_fingerprint: trace.fingerprint,
        events: trace.events.len(),
        tenants,
        last_slot: replay_slot.load(Ordering::Relaxed),
        class_ops,
        faults_planned: plan.faults.len(),
        faults_fired: fired.len(),
        misrouted: fleet.misrouted,
        policy_routed: fleet.policy_routed,
        rerouted_on_failure: fleet.rerouted_on_failure,
        admission_denied: fleet.admission_denied,
        respawns: fleet.respawns(),
        fleet_ops: fleet.ops,
        crosscheck_sampled: fleet.crosscheck_sampled(),
        crosscheck_mismatches: fleet.crosscheck_mismatches(),
        fleet_pj_per_op: fleet.fleet_energy.pj_per_op,
        sustained_ops_per_s: stats.completed_ops as f64 / wall_secs.max(1e-9),
        conservation_ok: fleet.conservation_ok(),
        results_in_digest,
        digest,
        wall_secs,
        producer: stats,
    };
    Ok(ReplayOutcome { report, fleet })
}

/// One replay tenant: walks its events in arrival order, turning gaps
/// into idle accounting and ops into resilient submissions, and lands
/// every outcome in exactly one ledger column. Returns `Err` only for
/// harness-level corruption (a *short* successful result).
fn trace_tenant(
    router: &ServeRouter,
    tier: Fidelity,
    events: &[crate::runtime::trace::TraceEvent],
    deadline: Duration,
    retry: RetryPolicy,
    submitted_ops: &AtomicU64,
    replay_slot: &AtomicU64,
) -> crate::Result<ProducerStats> {
    let mut st = ProducerStats::default();
    let mut checksum = FNV_OFFSET;
    for e in events {
        replay_slot.fetch_max(e.slot, Ordering::Relaxed);
        if e.idle_before > 0 {
            // Idle on a shard that happens to be mid-respawn is dropped
            // (retryable error) — an idle gap is not work anyone loses.
            let _ = router.submit_idle(e.class, tier, e.idle_before * IDLE_OPS_PER_SLOT);
        }
        let mut stream =
            OperandStream::new(e.class.precision, OperandMix::Finite, e.op_seed);
        let triples = stream.batch(e.ops as usize);
        st.submitted_subs += 1;
        st.submitted_ops += e.ops;
        submitted_ops.fetch_add(e.ops, Ordering::Relaxed);
        match router.submit_with_retry_seeded(
            e.class,
            tier,
            &triples,
            Some(deadline),
            retry,
            e.op_seed,
        ) {
            Ok(out) => {
                anyhow::ensure!(
                    out.bits.len() == e.ops as usize,
                    "short result: {} of {}",
                    out.bits.len(),
                    e.ops
                );
                for b in &out.bits {
                    checksum = fnv1a_fold(checksum, *b);
                }
                st.completed_subs += 1;
                st.completed_ops += e.ops;
                st.retries += u64::from(out.retries);
            }
            Err(err) => {
                if ServeError::classify(&err) == Some(ServeError::DeadlineExceeded) {
                    st.hung_subs += 1;
                    st.hung_ops += e.ops;
                } else {
                    st.errored_subs += 1;
                    st.errored_ops += e.ops;
                }
            }
        }
    }
    st.checksums.push(checksum);
    Ok(st)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::generator::FpuConfig;
    use crate::workloads::throughput::{OperandMix, OperandStream};

    #[test]
    fn datapath_only_all_units_clean() {
        for cfg in FpuConfig::fpmax_units() {
            let unit = FpuUnit::generate(&cfg);
            let mut s = OperandStream::new(cfg.precision, OperandMix::Finite, 77);
            let triples = s.batch(4000);
            let r = verify_datapath_only(&unit, &triples, 4);
            assert!(r.datapath_mismatches.is_empty(), "{}: {:?}", cfg.name(), r.datapath_mismatches.first());
            assert_eq!(r.ops, 4000);
        }
    }

    #[test]
    fn datapath_handles_specials_cleanly() {
        let cfg = FpuConfig::sp_fma();
        let unit = FpuUnit::generate(&cfg);
        let mut s = OperandStream::new(cfg.precision, OperandMix::Anything, 13);
        let triples = s.batch(4000);
        let r = verify_datapath_only(&unit, &triples, 4);
        assert!(r.datapath_mismatches.is_empty(), "{:?}", r.datapath_mismatches.first());
    }

    #[test]
    fn worker_counts_agree() {
        let cfg = FpuConfig::dp_cma();
        let unit = FpuUnit::generate(&cfg);
        let mut s = OperandStream::new(cfg.precision, OperandMix::Finite, 5);
        let triples = s.batch(1003); // deliberately not divisible
        for workers in [1, 2, 3, 8, 64] {
            let r = verify_datapath_only(&unit, &triples, workers);
            assert_eq!(r.ops, 1003);
            assert!(r.datapath_mismatches.is_empty(), "workers={workers}");
        }
    }

    #[test]
    fn traced_verification_clean_with_exact_window_sums() {
        let cfg = FpuConfig::sp_fma();
        let unit = FpuUnit::generate(&cfg);
        let mut s = OperandStream::new(cfg.precision, OperandMix::Anything, 31);
        let triples = s.batch(3_000);
        for tier in [Fidelity::GateLevel, Fidelity::WordSimd] {
            let (r, trace) = verify_datapath_traced(&unit, tier, &triples, 4, 500);
            assert!(r.datapath_mismatches.is_empty(), "{tier:?}");
            assert_eq!(r.ops, 3_000);
            assert_eq!(trace.len(), 6);
            assert_eq!(trace.total_ops(), 3_000);
            assert_eq!(trace.aggregate().ops, 3_000);
        }
    }

    #[test]
    fn same_value_nan_insensitive() {
        let qnan = 0x7fc0_0000u64;
        let other_nan = 0x7fc0_0001u64;
        assert!(same_value(Precision::Single, qnan, other_nan));
        assert!(!same_value(Precision::Single, qnan, 0x7f80_0000));
        assert!(same_value(Precision::Single, 5, 5));
    }

    #[test]
    fn mismatches_are_reported_and_capped() {
        // Compare a stream against deliberately corrupted expectations.
        let cfg = FpuConfig::sp_fma();
        let unit = FpuUnit::generate(&cfg);
        let mut s = OperandStream::new(cfg.precision, OperandMix::Finite, 9);
        let triples = s.batch(100);
        let exec = BatchExecutor::serial();
        let got = exec.run(&unit, &triples);
        let mut want = got.clone();
        for w in want.iter_mut() {
            *w ^= 1; // flip the LSB of every expectation
        }
        let m = collect_mismatches(cfg.precision, &triples, &got, &want);
        assert_eq!(m.len(), MISMATCH_CAP);
        assert_eq!(m[0].index, 0);
        assert_eq!(m[0].got ^ 1, m[0].want);
    }
}
