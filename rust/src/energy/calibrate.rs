//! Calibration of the model's free constants against Table I — the
//! reproduction's "fit once, then predict" discipline.
//!
//! The full model has exactly **six fitted constants**; everything else
//! is structural (derived from the generator's output) or standard
//! technology physics:
//!
//! | constant | fitted from | value |
//! |---|---|---|
//! | κ_latency (sizing) | DP/SP CMA nominal frequencies | 2.74 |
//! | κ_throughput | DP/SP FMA nominal frequencies | 4.03 |
//! | C_LOGIC_PJ_V2 | the four dynamic-energy points | 0.0117 |
//! | C_REG_PJ_V2 | (jointly with C_LOGIC) | 0.0137 |
//! | AREA_UM2 per style | the four area points | 6.57 / 3.89 |
//! | leak_density | the four leakage points | 14.7 mW/mm² |
//!
//! This module recomputes each implied constant from the published
//! numbers so the fit is auditable; its tests fail if the constants in
//! [`components`]/[`pipeline`]/[`tech`] drift from what Table I implies.

use crate::arch::generator::{FpuConfig, FpuUnit};
use crate::energy::components::unit_cost;
use crate::energy::tech::{OperatingPoint, Technology};
use crate::timing::{nominal_op, stage_depth_fo4, DesignStyle};
use crate::util::stats::geomean;

/// One unit's published nominal row from Table I.
#[derive(Debug, Clone, Copy)]
pub struct Table1Row {
    pub cfg: fn() -> FpuConfig,
    pub area_mm2: f64,
    pub vdd: f64,
    pub vbb: f64,
    pub freq_ghz: f64,
    pub leak_mw: f64,
    pub total_mw: f64,
}

/// The four fabricated units' published nominal rows.
pub const TABLE1: [Table1Row; 4] = [
    Table1Row { cfg: FpuConfig::dp_cma, area_mm2: 0.032, vdd: 0.9, vbb: 1.2, freq_ghz: 1.19, leak_mw: 8.4, total_mw: 66.0 },
    Table1Row { cfg: FpuConfig::dp_fma, area_mm2: 0.024, vdd: 0.8, vbb: 1.2, freq_ghz: 0.91, leak_mw: 3.8, total_mw: 41.0 },
    Table1Row { cfg: FpuConfig::sp_cma, area_mm2: 0.018, vdd: 0.8, vbb: 1.2, freq_ghz: 1.36, leak_mw: 3.3, total_mw: 25.0 },
    Table1Row { cfg: FpuConfig::sp_fma, area_mm2: 0.0081, vdd: 0.9, vbb: 1.2, freq_ghz: 0.91, leak_mw: 1.6, total_mw: 17.0 },
];

/// κ implied by one unit's published frequency: the sizing factor that
/// makes `stage_fo4 · κ · FO4(op)` equal the silicon cycle time.
pub fn implied_kappa(row: &Table1Row, tech: &Technology) -> f64 {
    let cfg = (row.cfg)();
    let fo4 = tech.fo4_ps(OperatingPoint::new(row.vdd, row.vbb)).expect("nominal point valid");
    let cycle_ps = 1000.0 / row.freq_ghz;
    cycle_ps / (stage_depth_fo4(&cfg) * fo4)
}

/// Leakage density (mW/mm² at V_DD=1, zero bias) implied by one row.
pub fn implied_leak_density(row: &Table1Row, tech: &Technology) -> f64 {
    let dvt = tech.body_coeff * row.vbb;
    row.leak_mw / (row.area_mm2 * row.vdd * 10f64.powf(dvt / tech.subthreshold_swing))
}

/// Dynamic energy per op implied by one row: (P_total − P_leak)/f, in pJ.
pub fn implied_dyn_energy_pj(row: &Table1Row) -> f64 {
    (row.total_mw - row.leak_mw) / row.freq_ghz
}

/// Full calibration report, printable from the CLI.
#[derive(Debug, Clone)]
pub struct CalibrationReport {
    pub kappa_latency: f64,
    pub kappa_throughput: f64,
    pub leak_density: f64,
    /// Per-unit (name, model/silicon ratios) for freq, dyn energy, area,
    /// leakage.
    pub residuals: Vec<(String, f64, f64, f64, f64)>,
}

/// Recompute every implied constant and the per-unit residuals of the
/// committed model.
pub fn calibration_report() -> CalibrationReport {
    let tech = Technology::fdsoi28();
    let mut k_lat = Vec::new();
    let mut k_thr = Vec::new();
    let mut leak = Vec::new();
    let mut residuals = Vec::new();
    for row in &TABLE1 {
        let cfg = (row.cfg)();
        match DesignStyle::of(&cfg) {
            DesignStyle::Latency => k_lat.push(implied_kappa(row, &tech)),
            DesignStyle::Throughput => k_thr.push(implied_kappa(row, &tech)),
        }
        leak.push(implied_leak_density(row, &tech));

        let unit = FpuUnit::generate(&cfg);
        let cost = unit_cost(&unit);
        let t = crate::timing::timing(&cfg, &tech, nominal_op(&cfg)).unwrap();
        let freq_ratio = t.freq_ghz / row.freq_ghz;
        let dyn_ratio = cost.dyn_energy_pj(row.vdd, 1.0) / implied_dyn_energy_pj(row);
        let area_ratio = cost.area_mm2 / row.area_mm2;
        let leak_ratio =
            tech.leakage_mw(cost.area_mm2, OperatingPoint::new(row.vdd, row.vbb)) / row.leak_mw;
        residuals.push((cfg.name(), freq_ratio, dyn_ratio, area_ratio, leak_ratio));
    }
    CalibrationReport {
        kappa_latency: geomean(&k_lat),
        kappa_throughput: geomean(&k_thr),
        leak_density: geomean(&leak),
        residuals,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::rel_diff;
    use crate::energy::components::logic_cells;

    #[test]
    fn committed_kappas_match_implied() {
        let r = calibration_report();
        assert!(
            rel_diff(r.kappa_latency, DesignStyle::Latency.kappa()) < 0.05,
            "κ_lat drifted: implied {:.2} vs committed {:.2}",
            r.kappa_latency,
            DesignStyle::Latency.kappa()
        );
        assert!(
            rel_diff(r.kappa_throughput, DesignStyle::Throughput.kappa()) < 0.05,
            "κ_thr drifted: implied {:.2} vs committed {:.2}",
            r.kappa_throughput,
            DesignStyle::Throughput.kappa()
        );
        // The styles are genuinely distinct sizing regimes.
        assert!(r.kappa_throughput > r.kappa_latency * 1.15);
    }

    #[test]
    fn committed_leak_density_matches_implied() {
        let r = calibration_report();
        let tech = Technology::fdsoi28();
        assert!(
            rel_diff(r.leak_density, tech.leak_density_mw_mm2) < 0.08,
            "leak density drifted: implied {:.1} vs committed {:.1}",
            r.leak_density,
            tech.leak_density_mw_mm2
        );
    }

    #[test]
    fn per_unit_residuals_bounded() {
        // Freq ≤15%, dyn energy ≤12%, area ≤25%, leakage ≤35% — the fit
        // quality documented in DESIGN.md.
        for (name, f, e, a, l) in calibration_report().residuals {
            assert!((f - 1.0).abs() < 0.15, "{name} freq residual {f:.2}");
            assert!((e - 1.0).abs() < 0.12, "{name} dyn-energy residual {e:.2}");
            assert!((a - 1.0).abs() < 0.25, "{name} area residual {a:.2}");
            assert!((l - 1.0).abs() < 0.40, "{name} leak residual {l:.2}");
        }
    }

    #[test]
    fn implied_energy_coefficients_consistent() {
        // Re-derive (C_LOGIC, C_REG) from the two DP rows (the 2×2 system
        // used for the committed fit) and check the committed constants.
        let tech = Technology::fdsoi28();
        let _ = &tech;
        let rows = [&TABLE1[0], &TABLE1[1]];
        let mut m = [[0.0f64; 2]; 2];
        let mut b = [0.0f64; 2];
        for (i, row) in rows.iter().enumerate() {
            let cfg = (row.cfg)();
            let unit = FpuUnit::generate(&cfg);
            m[i][0] = logic_cells(&cfg, unit.structure());
            m[i][1] = unit.structure().register_bits as f64;
            b[i] = implied_dyn_energy_pj(row) / (row.vdd * row.vdd);
        }
        let det = m[0][0] * m[1][1] - m[0][1] * m[1][0];
        let c_logic = (b[0] * m[1][1] - b[1] * m[0][1]) / det;
        let c_reg = (m[0][0] * b[1] - m[1][0] * b[0]) / det;
        assert!(rel_diff(c_logic, crate::energy::components::C_LOGIC_PJ_V2) < 0.06,
                "C_LOGIC implied {c_logic:.4}");
        assert!(rel_diff(c_reg, crate::energy::components::C_REG_PJ_V2) < 0.10,
                "C_REG implied {c_reg:.4}");
    }

    #[test]
    fn report_covers_all_units() {
        let r = calibration_report();
        assert_eq!(r.residuals.len(), 4);
        let names: Vec<&str> = r.residuals.iter().map(|(n, ..)| n.as_str()).collect();
        assert!(names.contains(&"SP FMA") && names.contains(&"DP CMA"));
    }
}
