//! Power and efficiency at an operating point: the quantities every
//! figure of the paper plots.
//!
//! Conventions (matching the paper's): one FMAC = **2 FLOPs**;
//! efficiency metrics are *normalized* (at the achieved frequency of the
//! operating point) — "GFLOPS/W" = 2·f·u / P_total, "GFLOPS/mm²" =
//! 2·f·u / area — with utilization u = 1 unless stated.

use crate::arch::engine::{ActivityAccumulator, ActivityTrace};
use crate::arch::generator::{FpuConfig, FpuUnit};
use crate::timing::{self, Timing};

use super::components::{unit_cost, UnitCost};
use super::tech::{OperatingPoint, Technology};

/// Power split at an operating point.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PowerBreakdown {
    pub dynamic_mw: f64,
    pub leakage_mw: f64,
}

impl PowerBreakdown {
    pub fn total_mw(&self) -> f64 {
        self.dynamic_mw + self.leakage_mw
    }
}

/// A fully evaluated operating point of one unit — a single dot on the
/// paper's Fig. 3 / Fig. 4 axes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EfficiencyPoint {
    pub op: OperatingPoint,
    pub freq_ghz: f64,
    pub power: PowerBreakdown,
    /// Energy per FLOP in pJ (total power over delivered FLOPS).
    pub pj_per_flop: f64,
    /// 2·f·u / P — the paper's energy-efficiency axis.
    pub gflops_per_w: f64,
    /// 2·f·u / area — the paper's area-efficiency axis.
    pub gflops_per_mm2: f64,
    /// Utilization the point was evaluated at.
    pub utilization: f64,
}

/// Evaluate a unit at an operating point and utilization.
///
/// `utilization` models duty cycle with clock gating: dynamic power
/// scales with u (issue slots actually used); leakage does not — the
/// Fig. 4 energy blow-up at 10% utilization is exactly this term.
pub fn evaluate(
    unit: &FpuUnit,
    tech: &Technology,
    op: OperatingPoint,
    utilization: f64,
) -> Option<EfficiencyPoint> {
    let cost = unit_cost(unit);
    let t = timing::timing(&unit.config, tech, op)?;
    Some(evaluate_with(&unit.config, &cost, &t, tech, op, utilization))
}

/// Evaluate a unit with a **measured** activity scale from the unified
/// execution engine's [`ActivityAccumulator`] — this is how batches that
/// actually ran (coordinator verifications, DSE operand samples, chip
/// programs) feed their observed datapath activity back into the energy
/// model, replacing the old fixed average-activity assumption.
pub fn evaluate_measured(
    unit: &FpuUnit,
    tech: &Technology,
    op: OperatingPoint,
    utilization: f64,
    activity: &ActivityAccumulator,
) -> Option<EfficiencyPoint> {
    let cost = unit_cost(unit);
    let t = timing::timing(&unit.config, tech, op)?;
    let scale = activity.activity_scale(unit.structure());
    Some(evaluate_with_activity(&cost, &t, tech, op, utilization, scale))
}

/// Evaluation core for callers that already computed cost/timing (the
/// DSE sweep reuses both across thousands of points).
pub fn evaluate_with(
    _cfg: &FpuConfig,
    cost: &UnitCost,
    t: &Timing,
    tech: &Technology,
    op: OperatingPoint,
    utilization: f64,
) -> EfficiencyPoint {
    evaluate_with_activity(cost, t, tech, op, utilization, 1.0)
}

/// Evaluation core with an explicit data-activity scale (1.0 = the
/// calibrated average-operand activity; see
/// [`ActivityAccumulator::activity_scale`]).
pub fn evaluate_with_activity(
    cost: &UnitCost,
    t: &Timing,
    tech: &Technology,
    op: OperatingPoint,
    utilization: f64,
    activity_scale: f64,
) -> EfficiencyPoint {
    assert!((0.0..=1.0).contains(&utilization), "utilization out of range");
    let e_op_pj = cost.dyn_energy_pj(op.vdd, activity_scale);
    // pJ · Gop/s = mW.
    let dynamic_mw = e_op_pj * t.freq_ghz * utilization;
    let leakage_mw = tech.leakage_mw(cost.area_mm2, op);
    let power = PowerBreakdown { dynamic_mw, leakage_mw };
    let gflops = 2.0 * t.freq_ghz * utilization; // FMAC = 2 FLOPs
    let pj_per_flop = if gflops > 0.0 { power.total_mw() / gflops } else { f64::INFINITY };
    EfficiencyPoint {
        op,
        freq_ghz: t.freq_ghz,
        power,
        pj_per_flop,
        gflops_per_w: if power.total_mw() > 0.0 { 1000.0 * gflops / power.total_mw() } else { 0.0 },
        gflops_per_mm2: gflops / cost.area_mm2,
        utilization,
    }
}

/// Window-granular energy integration of a time-resolved trace under a
/// per-window body-bias schedule.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WindowedEnergy {
    /// Windows integrated.
    pub windows: usize,
    /// Ops executed across the trace.
    pub ops: u64,
    /// Issue slots (ops + idle) across the trace.
    pub slots: u64,
    /// Dynamic energy, pJ (per-window measured activity scale applied).
    pub dynamic_pj: f64,
    /// Leakage energy, pJ — integrated at **each window's own bias
    /// point** instead of one static V_BB.
    pub leakage_pj: f64,
    /// Energy per op, pJ.
    pub pj_per_op: f64,
}

/// Integrate a trace's energy window by window: each window's dynamic
/// energy uses its measured activity scale, and its leakage is evaluated
/// at the bias point `vbb[w]` the controller scheduled for it (see
/// [`crate::bb::window_bias_schedule`]) — replacing the single static
/// V_BB of [`evaluate`]/[`evaluate_measured`].
///
/// Timing (and therefore real time per slot) comes from the *active*
/// operating point `(vdd, vbb_active)`; the unit never computes under a
/// dropped bias. Bias-transition energy is settle-window leakage at the
/// active level, which the schedule already encodes by holding the edge
/// windows of each gap at `vbb_active` — the finer sub-window transition
/// accounting lives in [`crate::bb::run_energy_trace`].
pub fn evaluate_windowed(
    unit: &FpuUnit,
    tech: &Technology,
    vdd: f64,
    vbb_active: f64,
    trace: &ActivityTrace,
    vbb: &[f64],
) -> Option<WindowedEnergy> {
    assert_eq!(vbb.len(), trace.len(), "one bias point per window");
    let cost = unit_cost(unit);
    let s = unit.structure();
    let t = timing::timing(&unit.config, tech, OperatingPoint::new(vdd, vbb_active))?;
    let cycle_s = t.cycle_ps * 1e-12;
    let mut ops = 0u64;
    let mut slots = 0u64;
    let mut dynamic = 0.0f64;
    let mut leakage = 0.0f64;
    for (w, &vbb_w) in trace.windows().iter().zip(vbb) {
        ops += w.acc.ops;
        slots += w.slots;
        dynamic +=
            w.acc.ops as f64 * (cost.dyn_energy_pj(vdd, w.acc.activity_scale(s)) * 1e-12);
        let leak_w = tech.leakage_mw(cost.area_mm2, OperatingPoint::new(vdd, vbb_w)) * 1e-3;
        leakage += leak_w * (w.slots as f64 * cycle_s);
    }
    let total = dynamic + leakage;
    Some(WindowedEnergy {
        windows: trace.len(),
        ops,
        slots,
        dynamic_pj: dynamic * 1e12,
        leakage_pj: leakage * 1e12,
        pj_per_op: if ops > 0 { total * 1e12 / ops as f64 } else { f64::INFINITY },
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::generator::FpuConfig;
    use crate::timing::nominal_op;
    use crate::util::stats::rel_diff;

    fn eval_nominal(cfg: FpuConfig) -> EfficiencyPoint {
        let unit = FpuUnit::generate(&cfg);
        let tech = Technology::fdsoi28();
        evaluate(&unit, &tech, nominal_op(&cfg), 1.0).unwrap()
    }

    #[test]
    fn table1_total_power() {
        // Table I "Total Power" at the nominal points.
        let cases = [
            (FpuConfig::dp_cma(), 66.0),
            (FpuConfig::dp_fma(), 41.0),
            (FpuConfig::sp_cma(), 25.0),
            (FpuConfig::sp_fma(), 17.0),
        ];
        for (cfg, want_mw) in cases {
            let p = eval_nominal(cfg).power.total_mw();
            let rel = rel_diff(p, want_mw);
            assert!(
                rel < 0.25,
                "{}: model {p:.1} mW vs silicon {want_mw} mW (rel {rel:.2})",
                cfg.name()
            );
        }
    }

    #[test]
    fn table1_normalized_efficiencies() {
        // The paper's headline normalized numbers (Table I bottom rows).
        let cases = [
            // (cfg, GFLOPS/mm², GFLOPS/W)
            (FpuConfig::dp_cma(), 74.6, 36.0),
            (FpuConfig::dp_fma(), 74.6, 43.7),
            (FpuConfig::sp_cma(), 151.0, 110.0),
            (FpuConfig::sp_fma(), 217.0, 106.0),
        ];
        for (cfg, want_mm2, want_w) in cases {
            let p = eval_nominal(cfg);
            assert!(
                rel_diff(p.gflops_per_mm2, want_mm2) < 0.35,
                "{}: {:.0} GFLOPS/mm² vs {want_mm2}",
                cfg.name(),
                p.gflops_per_mm2
            );
            assert!(
                rel_diff(p.gflops_per_w, want_w) < 0.35,
                "{}: {:.0} GFLOPS/W vs {want_w}",
                cfg.name(),
                p.gflops_per_w
            );
        }
    }

    #[test]
    fn sp_fma_is_most_efficient_per_area() {
        // The headline claim: SP FMA leads the pack on area efficiency.
        let units = [FpuConfig::dp_cma(), FpuConfig::dp_fma(), FpuConfig::sp_cma()];
        let sp_fma = eval_nominal(FpuConfig::sp_fma());
        for cfg in units {
            assert!(sp_fma.gflops_per_mm2 > eval_nominal(cfg).gflops_per_mm2);
        }
    }

    #[test]
    fn low_utilization_explodes_energy_per_op() {
        // Fig. 4's 10%-utilization story at a fixed forward-biased point:
        // energy/FLOP rises steeply because leakage doesn't scale down.
        let unit = FpuUnit::generate(&FpuConfig::sp_cma());
        let tech = Technology::fdsoi28();
        let op = nominal_op(&FpuConfig::sp_cma());
        let full = evaluate(&unit, &tech, op, 1.0).unwrap();
        let idle = evaluate(&unit, &tech, op, 0.1).unwrap();
        let blowup = idle.pj_per_flop / full.pj_per_flop;
        assert!(blowup > 1.5, "expected a leakage-driven blow-up, got {blowup:.2}×");
        // Leakage is identical; dynamic scaled by 10×.
        assert!((idle.power.leakage_mw - full.power.leakage_mw).abs() < 1e-12);
        assert!((full.power.dynamic_mw / idle.power.dynamic_mw - 10.0).abs() < 1e-9);
    }

    #[test]
    fn lower_vdd_improves_energy_per_flop_until_leakage_wins() {
        // The Fig. 3 energy-vs-performance curve must be non-monotonic:
        // V² savings dominate at first, leakage-per-op dominates at the
        // bottom.
        let unit = FpuUnit::generate(&FpuConfig::sp_fma());
        let tech = Technology::fdsoi28();
        let mut best_v = 0.0;
        let mut best_e = f64::INFINITY;
        for i in 0..75 {
            let vdd = 0.36 + i as f64 * 0.01;
            if let Some(p) = evaluate(&unit, &tech, OperatingPoint::new(vdd, 1.2), 1.0) {
                if p.pj_per_flop < best_e {
                    best_e = p.pj_per_flop;
                    best_v = vdd;
                }
            }
        }
        // The optimum sits strictly inside the sweep (leakage-per-op loses
        // to V² only above the minimum-energy voltage).
        assert!(best_v > 0.37 && best_v < 1.0, "energy optimum at {best_v:.2} V");
        let nominal = evaluate(&unit, &tech, OperatingPoint::new(0.9, 1.2), 1.0).unwrap();
        assert!(best_e < nominal.pj_per_flop);
    }

    #[test]
    fn measured_activity_feeds_energy() {
        use crate::arch::engine::BatchExecutor;
        use crate::workloads::throughput::{OperandMix, OperandStream};
        let cfg = FpuConfig::sp_fma();
        let unit = FpuUnit::generate(&cfg);
        let tech = Technology::fdsoi28();
        let op = nominal_op(&cfg);
        let triples =
            OperandStream::new(cfg.precision, OperandMix::Finite, 42).batch(2_000);
        let (_, acc) = BatchExecutor::new(4).run_tracked(&unit, &triples);
        assert_eq!(acc.ops, 2_000);
        let measured = evaluate_measured(&unit, &tech, op, 1.0, &acc).unwrap();
        let modeled = evaluate(&unit, &tech, op, 1.0).unwrap();
        // Leakage is activity-independent; dynamic power moves with the
        // measured toggle scale (register clocking stays fixed, so the
        // ratio is bounded by the pure-logic scale).
        assert!((measured.power.leakage_mw - modeled.power.leakage_mw).abs() < 1e-12);
        let scale = acc.activity_scale(unit.structure());
        assert!(scale > 0.0 && scale <= 2.0, "scale {scale}");
        let expect_lower = scale < 1.0;
        assert_eq!(
            measured.power.dynamic_mw < modeled.power.dynamic_mw,
            expect_lower,
            "dynamic {} vs modeled {} at scale {scale}",
            measured.power.dynamic_mw,
            modeled.power.dynamic_mw
        );
    }

    #[test]
    fn zero_utilization_gives_infinite_energy_per_flop() {
        let unit = FpuUnit::generate(&FpuConfig::sp_fma());
        let tech = Technology::fdsoi28();
        let p = evaluate(&unit, &tech, OperatingPoint::new(0.9, 1.2), 0.0).unwrap();
        assert!(p.pj_per_flop.is_infinite());
        assert_eq!(p.gflops_per_w, 0.0);
    }

    #[test]
    fn windowed_integration_tracks_per_window_bias() {
        use crate::bb::{window_bias_schedule, BbPolicy};
        use crate::workloads::utilization::UtilizationProfile;
        let unit = FpuUnit::generate(&FpuConfig::sp_cma());
        let tech = Technology::fdsoi28();
        let profile = UtilizationProfile::duty(0.1, 10_000, 200_000);
        let trace = ActivityTrace::from_profile(&profile, 1_000);
        let vdd = 0.6;
        let adaptive = BbPolicy::Adaptive { vbb_active: 1.2, vbb_idle: 0.0, settle_cycles: 1_000 };
        let sched_a = window_bias_schedule(adaptive, &trace);
        let sched_s = window_bias_schedule(BbPolicy::static_nominal(), &trace);
        let ea = evaluate_windowed(&unit, &tech, vdd, 1.2, &trace, &sched_a).unwrap();
        let es = evaluate_windowed(&unit, &tech, vdd, 1.2, &trace, &sched_s).unwrap();
        assert_eq!(ea.ops, profile.active_cycles());
        assert_eq!(ea.slots, profile.total_cycles());
        // Identical dynamic energy, strictly lower leakage once idle
        // windows sit at the dropped bias.
        assert_eq!(ea.dynamic_pj, es.dynamic_pj);
        assert!(ea.leakage_pj < es.leakage_pj);
        assert!(ea.pj_per_op < es.pj_per_op);
        // A flat active schedule reproduces the static leakage integral
        // of the same timeline to round-off.
        let flat = evaluate(&unit, &tech, OperatingPoint::new(vdd, 1.2), 1.0).unwrap();
        let t = crate::timing::timing(&unit.config, &tech, OperatingPoint::new(vdd, 1.2)).unwrap();
        let total_s = profile.total_cycles() as f64 * t.cycle_ps * 1e-12;
        let want_leak_pj = flat.power.leakage_mw * 1e-3 * total_s * 1e12;
        assert!((es.leakage_pj / want_leak_pj - 1.0).abs() < 1e-9);
    }
}
