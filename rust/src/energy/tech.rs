//! 28nm UTBB FDSOI technology model: threshold voltage vs body bias,
//! α-power-law gate delay, and subthreshold leakage.
//!
//! UTBB FDSOI's headline feature — the one the paper's title advertises —
//! is its wide-range **body-bias** control: the thin buried oxide lets a
//! back-gate voltage V_BB shift V_t by ~85 mV/V over ±2 V without
//! junction leakage, far beyond bulk CMOS's ~25 mV/V. Forward bias (the
//! chip's 1.2 V setting) lowers V_t → faster gates at the same V_DD but
//! exponentially more leakage; reverse bias raises V_t → slow but
//! low-leak sleep. The paper's Fig. 4 exploits exactly this lever
//! dynamically.
//!
//! Model equations (standard EDA-textbook forms, constants chosen for ST
//! 28nm FDSOI LVT and calibrated against Table I in
//! [`crate::energy::calibrate`]):
//!
//! * `V_t(V_BB) = V_t0 − k_bb·V_BB`
//! * `t_FO4(V_DD, V_t) ∝ V_DD / (V_DD − V_t)^α`            (α-power law)
//! * `P_leak ∝ area · V_DD · 10^((V_t0 − V_t)/S)`           (subthreshold)

/// An operating point: supply and body-bias voltages.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OperatingPoint {
    /// Supply voltage in volts.
    pub vdd: f64,
    /// Forward body-bias voltage in volts (0 = no bias; negative =
    /// reverse bias).
    pub vbb: f64,
}

impl OperatingPoint {
    pub fn new(vdd: f64, vbb: f64) -> OperatingPoint {
        OperatingPoint { vdd, vbb }
    }
}

/// Technology constants for one process corner.
#[derive(Debug, Clone, PartialEq)]
pub struct Technology {
    pub name: &'static str,
    /// Drawn feature size in nm (for Table II scaling).
    pub feature_nm: f64,
    /// FO4 inverter delay in ps at (vdd_ref, V_BB = 0).
    pub fo4_ref_ps: f64,
    /// Reference supply for fo4_ref_ps.
    pub vdd_ref: f64,
    /// Zero-bias threshold voltage (LVT flavour).
    pub vt0: f64,
    /// α-power-law velocity-saturation exponent.
    pub alpha: f64,
    /// Body-bias coefficient in V/V (ΔV_t per volt of forward bias).
    pub body_coeff: f64,
    /// Subthreshold swing in V/decade.
    pub subthreshold_swing: f64,
    /// Leakage power density at (vdd_ref, V_t0), in mW/mm² — calibrated.
    pub leak_density_mw_mm2: f64,
    /// Valid supply range.
    pub vdd_min: f64,
    pub vdd_max: f64,
    /// Body-bias range (UTBB FDSOI allows a wide window).
    pub vbb_min: f64,
    pub vbb_max: f64,
}

impl Technology {
    /// ST 28nm UTBB FDSOI, LVT devices — the FPMax process.
    /// `leak_density_mw_mm2` is the value fitted from Table I's four
    /// leakage entries (see `energy::calibrate::tests`).
    pub fn fdsoi28() -> Technology {
        Technology {
            name: "ST 28nm UTBB FDSOI LVT",
            feature_nm: 28.0,
            fo4_ref_ps: 15.0,
            vdd_ref: 1.0,
            vt0: 0.36,
            alpha: 1.35,
            body_coeff: 0.085,
            subthreshold_swing: 0.085,
            leak_density_mw_mm2: 14.7,
            vdd_min: 0.35,
            vdd_max: 1.3,
            vbb_min: -2.0,
            vbb_max: 2.0,
        }
    }

    /// Threshold voltage at a body bias.
    pub fn vt(&self, vbb: f64) -> f64 {
        self.vt0 - self.body_coeff * vbb
    }

    /// FO4 delay in ps at an operating point (α-power law, normalized to
    /// the reference point). Returns `None` if the point cannot switch
    /// (V_DD too close to V_t for the model's validity).
    pub fn fo4_ps(&self, op: OperatingPoint) -> Option<f64> {
        let vt = self.vt(op.vbb);
        let overdrive = op.vdd - vt;
        if overdrive < 0.08 || op.vdd < self.vdd_min {
            return None;
        }
        let num = op.vdd / overdrive.powf(self.alpha);
        let den = self.vdd_ref / (self.vdd_ref - self.vt0).powf(self.alpha);
        Some(self.fo4_ref_ps * num / den)
    }

    /// Leakage power in mW for `area_mm2` of logic at an operating point.
    ///
    /// Forward body bias raises leakage exponentially (10^(ΔV_t/S)); the
    /// linear V_DD term captures the drain-bias dependence to first
    /// order.
    pub fn leakage_mw(&self, area_mm2: f64, op: OperatingPoint) -> f64 {
        let dvt = self.vt0 - self.vt(op.vbb); // >0 under forward bias
        self.leak_density_mw_mm2 * area_mm2 * (op.vdd / self.vdd_ref)
            * 10f64.powf(dvt / self.subthreshold_swing)
    }

    /// Is an operating point inside the technology's legal window?
    pub fn valid(&self, op: OperatingPoint) -> bool {
        op.vdd >= self.vdd_min
            && op.vdd <= self.vdd_max
            && op.vbb >= self.vbb_min
            && op.vbb <= self.vbb_max
            && self.fo4_ps(op).is_some()
    }

    /// The chip's nominal forward body bias (Table I: 1.2 V on all four
    /// units).
    pub const NOMINAL_VBB: f64 = 1.2;
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t() -> Technology {
        Technology::fdsoi28()
    }

    #[test]
    fn vt_shifts_with_body_bias() {
        let t = t();
        assert!((t.vt(0.0) - 0.36).abs() < 1e-12);
        // Paper's 1.2 V forward bias: ~100 mV threshold reduction.
        assert!((t.vt(1.2) - 0.258).abs() < 1e-9);
        // Reverse bias raises Vt.
        assert!(t.vt(-1.0) > t.vt(0.0));
    }

    #[test]
    fn fo4_reference_point() {
        let t = t();
        let d = t.fo4_ps(OperatingPoint::new(1.0, 0.0)).unwrap();
        assert!((d - t.fo4_ref_ps).abs() < 1e-9);
    }

    #[test]
    fn fo4_monotonic_in_vdd_and_bias() {
        let t = t();
        let mut prev = f64::INFINITY;
        for vdd in [0.5, 0.6, 0.7, 0.8, 0.9, 1.0, 1.1] {
            let d = t.fo4_ps(OperatingPoint::new(vdd, 0.0)).unwrap();
            assert!(d < prev, "fo4 must fall as vdd rises");
            prev = d;
        }
        // Forward body bias speeds gates up at fixed vdd.
        let slow = t.fo4_ps(OperatingPoint::new(0.7, 0.0)).unwrap();
        let fast = t.fo4_ps(OperatingPoint::new(0.7, 1.2)).unwrap();
        assert!(fast < slow);
    }

    #[test]
    fn fo4_rejects_subthreshold_operation() {
        let t = t();
        assert!(t.fo4_ps(OperatingPoint::new(0.40, -2.0)).is_none());
        assert!(t.fo4_ps(OperatingPoint::new(0.30, 0.0)).is_none());
    }

    #[test]
    fn leakage_exponential_in_bias() {
        let t = t();
        let base = t.leakage_mw(0.01, OperatingPoint::new(0.9, 0.0));
        let fwd = t.leakage_mw(0.01, OperatingPoint::new(0.9, 1.2));
        // 1.2 V forward bias → ΔVt = 102 mV → 10^1.2 ≈ 15.8×.
        assert!((fwd / base - 10f64.powf(0.102 / 0.085)).abs() < 1e-6);
        // Reverse bias cuts leakage by the same law.
        let rev = t.leakage_mw(0.01, OperatingPoint::new(0.9, -1.2));
        assert!(rev < base / 10.0);
    }

    #[test]
    fn leakage_scales_with_area_and_vdd() {
        let t = t();
        let p1 = t.leakage_mw(0.01, OperatingPoint::new(0.8, 0.6));
        let p2 = t.leakage_mw(0.02, OperatingPoint::new(0.8, 0.6));
        assert!((p2 / p1 - 2.0).abs() < 1e-12);
        let hi = t.leakage_mw(0.01, OperatingPoint::new(1.0, 0.6));
        assert!((hi / p1 - 1.0 / 0.8).abs() < 1e-9);
    }

    #[test]
    fn table1_leakage_magnitudes() {
        // With the calibrated density, the four Table-I leakage numbers
        // must come out within ~35% each (they scatter ±25% around any
        // single density — silicon variation the model cannot see).
        let t = t();
        let cases = [
            // (area mm², vdd, leak mW from Table I)
            (0.032, 0.9, 8.4), // DP CMA
            (0.024, 0.8, 3.8), // DP FMA
            (0.018, 0.8, 3.3), // SP CMA
            (0.0081, 0.9, 1.6), // SP FMA
        ];
        for (area, vdd, want) in cases {
            let got = t.leakage_mw(area, OperatingPoint::new(vdd, 1.2));
            let rel = (got - want).abs() / want;
            assert!(rel < 0.35, "area={area}: got {got:.2} mW want {want} mW (rel {rel:.2})");
        }
    }

    #[test]
    fn validity_window() {
        let t = t();
        assert!(t.valid(OperatingPoint::new(0.9, 1.2)));
        assert!(!t.valid(OperatingPoint::new(1.5, 0.0)));
        assert!(!t.valid(OperatingPoint::new(0.9, 3.0)));
        assert!(!t.valid(OperatingPoint::new(0.2, 0.0)));
    }
}
