//! Structure → cost mapping: effective switched capacitance and silicon
//! area for a generated FPU.
//!
//! Every cost is derived from the unit's [`StructureReport`] in
//! "FA-cell equivalents" (one 3:2 full-adder cell = 1.0), then converted
//! with **four calibrated coefficients** shared across all designs:
//!
//! * `C_LOGIC_PJ_V2` — switched capacitance per logic cell-equivalent per
//!   op (includes average datapath activity),
//! * `C_REG_PJ_V2` — per pipeline-register bit per cycle (clock + data),
//! * `AREA_UM2` per style — silicon area per cell-equivalent (registers
//!   count double); latency designs use delay-optimal (larger) sizing.
//!
//! The fit against Table I is reproduced in
//! [`crate::energy::calibrate`]; residuals are ≤ ~7% on energy and
//! ≤ ~17% on area — the scatter silicon shows around any structural
//! model.

use crate::arch::generator::{FpuConfig, FpuKind, FpuUnit, StructureReport};
use crate::timing::DesignStyle;

/// Switched capacitance per logic cell-equivalent, pJ/V² (i.e. energy at
/// V_DD=1V), average operand activity folded in.
pub const C_LOGIC_PJ_V2: f64 = 0.0117;

/// Switched capacitance per register bit (data + local clock), pJ/V².
pub const C_REG_PJ_V2: f64 = 0.0137;

/// Area per cell-equivalent, µm², by design style (registers ×2).
pub const AREA_UM2_LATENCY: f64 = 6.57;
pub const AREA_UM2_THROUGHPUT: f64 = 3.89;

/// Relative cell weight of common datapath structures (per bit).
mod weight {
    /// Booth mux row producing one PP bit.
    pub const PP_MUX: f64 = 0.6;
    /// Parallel-prefix CPA per bit (prefix tree amortized).
    pub const CPA: f64 = 2.0;
    /// Barrel shifter per bit.
    pub const SHIFTER: f64 = 1.2;
    /// LZA per bit.
    pub const LZA: f64 = 1.0;
    /// Rounder per result bit.
    pub const ROUNDER: f64 = 1.5;
    /// ×3 hard-multiple pre-adder per bit.
    pub const TRIPLE: f64 = 2.0;
    /// Exponent datapath (fixed block, cells).
    pub const EXP_BLOCK: f64 = 60.0;
}

/// The derived per-unit cost summary.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct UnitCost {
    /// Combinational cell-equivalents.
    pub logic_cells: f64,
    /// Pipeline register bits.
    pub register_bits: f64,
    /// Effective switched capacitance per op, pJ/V² (logic at average
    /// activity + registers).
    pub cap_pj_v2: f64,
    /// Silicon area in mm².
    pub area_mm2: f64,
}

impl UnitCost {
    /// Dynamic energy per FMAC op at a supply voltage, in pJ, scaled by a
    /// data-activity factor (1.0 = average operands; the coordinator can
    /// substitute measured toggle ratios).
    pub fn dyn_energy_pj(&self, vdd: f64, activity_scale: f64) -> f64 {
        // Registers clock at full activity; only the logic term scales
        // with operand activity.
        let logic = C_LOGIC_PJ_V2 * self.logic_cells * activity_scale;
        let regs = C_REG_PJ_V2 * self.register_bits;
        (logic + regs) * vdd * vdd
    }
}

/// Count the combinational cell-equivalents of a configuration.
pub fn logic_cells(cfg: &FpuConfig, s: &StructureReport) -> f64 {
    let m = s.sig_bits as f64;
    let window = s.mul_window as f64;
    let aw = s.adder_width as f64;
    let tree = s.tree_cells as f64 * s.wiring_factor;
    let pp = s.pp_count as f64 * window * weight::PP_MUX;
    let triple = if s.has_triple_adder { m * weight::TRIPLE } else { 0.0 };
    match cfg.kind {
        FpuKind::Fma => {
            // Carry-save product goes straight into the merge: no mul CPA.
            let merge = aw; // one 3:2 row
            let cpa = aw * weight::CPA;
            let lza = aw * weight::LZA;
            let norm = aw * weight::SHIFTER;
            let align = aw * weight::SHIFTER;
            let round = m * weight::ROUNDER;
            pp + triple + tree + merge + cpa + lza + norm + align + round + weight::EXP_BLOCK
        }
        FpuKind::Cma => {
            let mul_cpa = window * weight::CPA;
            let mul_round = m * weight::ROUNDER;
            let align = aw * weight::SHIFTER;
            let add_cpa = aw * weight::CPA;
            let lza = aw * weight::LZA;
            let norm = aw * weight::SHIFTER;
            let add_round = m * weight::ROUNDER;
            pp + triple
                + tree
                + mul_cpa
                + mul_round
                + align
                + add_cpa
                + lza
                + norm
                + add_round
                + weight::EXP_BLOCK
        }
    }
}

/// Derive the full cost summary for a generated unit.
pub fn unit_cost(unit: &FpuUnit) -> UnitCost {
    let s = unit.structure();
    let cells = logic_cells(&unit.config, s);
    let regs = s.register_bits as f64;
    let area_coeff = match DesignStyle::of(&unit.config) {
        DesignStyle::Latency => AREA_UM2_LATENCY,
        DesignStyle::Throughput => AREA_UM2_THROUGHPUT,
    };
    UnitCost {
        logic_cells: cells,
        register_bits: regs,
        cap_pj_v2: C_LOGIC_PJ_V2 * cells + C_REG_PJ_V2 * regs,
        area_mm2: area_coeff * (cells + 2.0 * regs) * 1e-6,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::generator::FpuConfig;
    use crate::util::stats::rel_diff;

    fn cost_of(cfg: FpuConfig) -> UnitCost {
        unit_cost(&FpuUnit::generate(&cfg))
    }

    /// Table I areas in mm².
    const TABLE1_AREA: [(fn() -> FpuConfig, f64); 4] = [
        (FpuConfig::dp_cma as fn() -> FpuConfig, 0.032),
        (FpuConfig::dp_fma, 0.024),
        (FpuConfig::sp_cma, 0.018),
        (FpuConfig::sp_fma, 0.0081),
    ];

    #[test]
    fn areas_match_table1() {
        for (mk, want) in TABLE1_AREA {
            let cfg = mk();
            let got = cost_of(cfg).area_mm2;
            let rel = rel_diff(got, want);
            assert!(
                rel < 0.25,
                "{}: model {got:.4} mm² vs silicon {want} mm² (rel {rel:.2})",
                cfg.name()
            );
        }
    }

    #[test]
    fn area_ordering_matches_table1() {
        // DP CMA > DP FMA > SP CMA > SP FMA.
        let a: Vec<f64> = [FpuConfig::dp_cma(), FpuConfig::dp_fma(), FpuConfig::sp_cma(), FpuConfig::sp_fma()]
            .iter()
            .map(|c| cost_of(*c).area_mm2)
            .collect();
        assert!(a[0] > a[1] && a[1] > a[2] && a[2] > a[3], "{a:?}");
    }

    #[test]
    fn dynamic_energy_matches_table1() {
        // Dyn energy at nominal = (P_total − P_leak)/f from Table I.
        let cases = [
            (FpuConfig::dp_cma(), 0.9, (66.0 - 8.4) / 1.19),
            (FpuConfig::dp_fma(), 0.8, (41.0 - 3.8) / 0.91),
            (FpuConfig::sp_cma(), 0.8, (25.0 - 3.3) / 1.36),
            (FpuConfig::sp_fma(), 0.9, (17.0 - 1.6) / 0.91),
        ];
        for (cfg, vdd, want_pj) in cases {
            let got = cost_of(cfg).dyn_energy_pj(vdd, 1.0);
            let rel = rel_diff(got, want_pj);
            assert!(
                rel < 0.12,
                "{}: model {got:.1} pJ vs silicon {want_pj:.1} pJ (rel {rel:.2})",
                cfg.name()
            );
        }
    }

    #[test]
    fn energy_scales_quadratically_with_vdd() {
        let c = cost_of(FpuConfig::sp_fma());
        let e1 = c.dyn_energy_pj(0.5, 1.0);
        let e2 = c.dyn_energy_pj(1.0, 1.0);
        assert!((e2 / e1 - 4.0).abs() < 1e-9);
    }

    #[test]
    fn activity_scales_logic_only() {
        let c = cost_of(FpuConfig::sp_fma());
        let quiet = c.dyn_energy_pj(0.9, 0.0);
        let busy = c.dyn_energy_pj(0.9, 1.0);
        // Register/clock power remains even with quiet data.
        assert!(quiet > 0.0);
        assert!(busy > quiet * 2.0);
    }

    #[test]
    fn booth3_cuts_tree_cost() {
        // The Table-I rationale for Booth-3 on the throughput units.
        let mut b2 = FpuConfig::sp_fma();
        b2.booth = crate::arch::booth::BoothRadix::Booth2;
        let c2 = cost_of(b2);
        let c3 = cost_of(FpuConfig::sp_fma());
        assert!(c3.logic_cells < c2.logic_cells);
    }
}
