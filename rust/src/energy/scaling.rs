//! Feature-size + FO4 technology scaling — the normalization rule behind
//! the paper's Table II comparison.
//!
//! The paper compares its SP FMA against four published designs
//! fabricated in 32–150 nm by scaling "area and power with the feature
//! sizes and the performance according to FO4", noting this "provides
//! numbers better than actual silicon" (optimistic classical scaling).
//! With `s = target_feature / source_feature` (< 1 when shrinking):
//!
//! * gate delay (FO4) ∝ feature         → frequency × 1/s
//! * area ∝ feature²                    → area × s²
//! * switched capacitance ∝ feature     → power = C·V²·f unchanged
//!
//! Hence **GFLOPS/W scales by 1/s** and **GFLOPS/mm² by 1/s³**.
//!
//! The four competitor entries carry the *raw* (source-node) numbers;
//! because the source papers are not available in this offline
//! environment, raw values are reconstructed by inverse-scaling the
//! published Table II entries — the forward rule below then reproduces
//! the table exactly, and the reconstructed raw values are sanity-checked
//! against the sources' known headline specs in the tests.

/// A published FPU design at its native process node.
#[derive(Debug, Clone, PartialEq)]
pub struct PublishedDesign {
    pub name: &'static str,
    pub reference: &'static str,
    pub feature_nm: f64,
    /// Area efficiency at the native node, GFLOPS/mm².
    pub raw_gflops_mm2: f64,
    /// Energy efficiency at the native node, GFLOPS/W.
    pub raw_gflops_w: f64,
}

/// Scaled efficiencies at a target node.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ScaledDesign {
    pub gflops_mm2: f64,
    pub gflops_w: f64,
}

impl PublishedDesign {
    /// Scale to a target feature size with the Table-II rule.
    pub fn scale_to(&self, target_nm: f64) -> ScaledDesign {
        let s = target_nm / self.feature_nm;
        ScaledDesign {
            gflops_mm2: self.raw_gflops_mm2 / (s * s * s),
            gflops_w: self.raw_gflops_w / s,
        }
    }

    /// The four comparison designs of Table II, with raw numbers
    /// reconstructed at their native nodes (see module docs).
    pub fn table2_competitors() -> Vec<PublishedDesign> {
        vec![
            PublishedDesign {
                name: "Variable-precision FMA",
                reference: "H. Kaul et al., ISSCC 2012 [4]",
                feature_nm: 32.0,
                // 28/32 ⇒ s=0.875: 62.5·s³ = 41.9, 52.8·s = 46.2.
                raw_gflops_mm2: 62.5 * 0.875f64.powi(3),
                raw_gflops_w: 52.8 * 0.875,
            },
            PublishedDesign {
                name: "Resonant FMA",
                reference: "J. Kao et al., ASSCC 2010 [5]",
                feature_nm: 45.0,
                raw_gflops_mm2: 142.0 * (28f64 / 45.0).powi(3),
                raw_gflops_w: 54.9 * (28.0 / 45.0),
            },
            PublishedDesign {
                name: "CELL FMA",
                reference: "H. Oh et al., JSSC 2006 [6]",
                feature_nm: 90.0,
                raw_gflops_mm2: 384.0 * (28f64 / 90.0).powi(3),
                raw_gflops_w: 66.0 * (28.0 / 90.0),
            },
            PublishedDesign {
                name: "Reconfig FPU",
                reference: "S. Jain et al., VLSI Design 2010 [7]",
                feature_nm: 90.0,
                raw_gflops_mm2: 0.8 * (28f64 / 90.0).powi(3),
                raw_gflops_w: 33.7 * (28.0 / 90.0),
            },
        ]
    }
}

/// The Table II target values (scaled to 28nm) for verification.
pub const TABLE2_SCALED: [(&str, f64, f64); 4] = [
    ("Variable-precision FMA", 62.5, 52.8),
    ("Resonant FMA", 142.0, 54.9),
    ("CELL FMA", 384.0, 66.0),
    ("Reconfig FPU", 0.8, 33.7),
];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::rel_diff;

    #[test]
    fn forward_scaling_reproduces_table2() {
        for (d, (name, want_mm2, want_w)) in
            PublishedDesign::table2_competitors().iter().zip(TABLE2_SCALED)
        {
            assert_eq!(d.name, name);
            let s = d.scale_to(28.0);
            assert!(rel_diff(s.gflops_mm2, want_mm2) < 1e-9, "{name} area eff");
            assert!(rel_diff(s.gflops_w, want_w) < 1e-9, "{name} energy eff");
        }
    }

    #[test]
    fn identity_at_native_node() {
        for d in PublishedDesign::table2_competitors() {
            let s = d.scale_to(d.feature_nm);
            assert!(rel_diff(s.gflops_mm2, d.raw_gflops_mm2) < 1e-12);
            assert!(rel_diff(s.gflops_w, d.raw_gflops_w) < 1e-12);
        }
    }

    #[test]
    fn shrinking_always_helps() {
        for d in PublishedDesign::table2_competitors() {
            let s = d.scale_to(20.0);
            assert!(s.gflops_mm2 > d.raw_gflops_mm2);
            assert!(s.gflops_w > d.raw_gflops_w);
        }
    }

    #[test]
    fn reconstructed_raw_values_plausible() {
        // CELL SPE FPU at 90nm: ~8 GFLOPS (4 GHz × 2) in under 1 mm² and
        // a few hundred mW → raw efficiencies of order 10 GFLOPS/mm² and
        // 20 GFLOPS/W. Our inverse-scaled values must land there.
        let cell = &PublishedDesign::table2_competitors()[2];
        assert!((5.0..25.0).contains(&cell.raw_gflops_mm2), "{}", cell.raw_gflops_mm2);
        assert!((10.0..40.0).contains(&cell.raw_gflops_w), "{}", cell.raw_gflops_w);
        // Kaul's 32nm design reported ~50 GFLOPS/W near nominal.
        let kaul = &PublishedDesign::table2_competitors()[0];
        assert!((30.0..60.0).contains(&kaul.raw_gflops_w));
    }

    #[test]
    fn fpmax_wins_energy_loses_peak_area_to_cell() {
        // The shape of Table II: FPMax SP FMA (217, 106) beats every
        // competitor on GFLOPS/W but CELL's scaled GFLOPS/mm² is higher.
        let fpmax = (217.0, 106.0);
        for (d, (_, mm2, w)) in
            PublishedDesign::table2_competitors().iter().zip(TABLE2_SCALED)
        {
            let s = d.scale_to(28.0);
            assert!(fpmax.1 > s.gflops_w, "{} should lose on energy", d.name);
            let _ = (mm2, w);
        }
        let cell = PublishedDesign::table2_competitors()[2].scale_to(28.0);
        assert!(cell.gflops_mm2 > fpmax.0, "CELL wins peak area efficiency");
    }
}
