//! Energy, power, and area models for the 28nm UTBB FDSOI process.
//!
//! [`tech`] holds the process physics (V_t vs body bias, α-power delay,
//! subthreshold leakage); [`components`] maps a generated unit's
//! structure to effective capacitance and silicon area; [`power`]
//! combines them into power/efficiency at an operating point and
//! activity; [`scaling`] implements the paper's Table-II feature-size +
//! FO4 normalization; [`calibrate`] documents the fit of the few free
//! constants to Table I.

pub mod calibrate;
pub mod components;
pub mod power;
pub mod scaling;
pub mod tech;

pub use components::UnitCost;
pub use power::{EfficiencyPoint, PowerBreakdown};
pub use tech::{OperatingPoint, Technology};
