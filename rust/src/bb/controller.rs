//! Body-bias control policies — the paper's second headline result.
//!
//! Fig. 4's experiment: a latency unit running a low-utilization
//! workload with the body bias **statically** set for speed (forward
//! bias, low V_t) leaks so much during the idle gaps that energy/op
//! rises ~3×. **Dynamically adapting** V_BB — dropping to zero/reverse
//! bias in idle periods — recovers most of it (≈1.5×).
//!
//! The adaptive policy is not free: the back-gate wells are an RC load
//! charged by a bias generator, so a transition takes ~1 µs during
//! which the unit either waits (wake-up latency) or leaks at the old
//! V_t. Both costs are modelled; the controller only wins when idle
//! periods are long compared to the settle time, exactly as the paper's
//! "lowering BB for low-utilization period" phrasing implies.

use crate::arch::generator::FpuUnit;
use crate::energy::components::unit_cost;
use crate::energy::tech::{OperatingPoint, Technology};
use crate::timing;
use crate::workloads::utilization::UtilizationProfile;

/// A body-bias policy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum BbPolicy {
    /// V_BB fixed for the whole run (the "statically set BB" curves).
    Static { vbb: f64 },
    /// V_BB dropped to `vbb_idle` when an idle period is detected and
    /// restored on wake-up.
    Adaptive {
        vbb_active: f64,
        vbb_idle: f64,
        /// Bias settle time in cycles (≈1 µs × f); leakage stays at the
        /// *higher* of the two bias levels while settling, and detection
        /// lags idle onset by the same amount.
        settle_cycles: u64,
    },
}

impl BbPolicy {
    /// The paper's nominal static policy (1.2 V forward).
    pub fn static_nominal() -> BbPolicy {
        BbPolicy::Static { vbb: Technology::NOMINAL_VBB }
    }

    /// The paper's adaptive policy: full forward bias when busy, zero
    /// bias when idle, with a settle time derived from the clock.
    pub fn adaptive_nominal(freq_ghz: f64) -> BbPolicy {
        BbPolicy::Adaptive {
            vbb_active: Technology::NOMINAL_VBB,
            vbb_idle: 0.0,
            settle_cycles: (1.0e3 * freq_ghz) as u64, // ≈1 µs
        }
    }
}

/// Energy accounting for one run of a profile under one policy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BbRunEnergy {
    /// FMAC ops executed (one per active cycle).
    pub ops: u64,
    pub dynamic_pj: f64,
    pub leakage_pj: f64,
    /// Extra leakage burned in bias transitions.
    pub transition_pj: f64,
    /// Energy per op, pJ.
    pub pj_per_op: f64,
}

/// Simulate the energy of running `profile` on `unit` at `vdd` under a
/// bias policy. The unit issues one FMAC per active cycle (the Fig. 4
/// latency units are kept fed during bursts) and is clock-gated when
/// idle.
pub fn run_energy(
    unit: &FpuUnit,
    tech: &Technology,
    vdd: f64,
    policy: BbPolicy,
    profile: &UtilizationProfile,
) -> Option<BbRunEnergy> {
    let cost = unit_cost(unit);
    let (vbb_active, vbb_idle, settle) = match policy {
        BbPolicy::Static { vbb } => (vbb, vbb, 0),
        BbPolicy::Adaptive { vbb_active, vbb_idle, settle_cycles } => {
            (vbb_active, vbb_idle, settle_cycles)
        }
    };
    // Timing is set by the *active* operating point; the unit never
    // computes under idle bias.
    let t = timing::timing(&unit.config, tech, OperatingPoint::new(vdd, vbb_active))?;
    let cycle_s = t.cycle_ps * 1e-12;
    let leak_active_w = tech.leakage_mw(cost.area_mm2, OperatingPoint::new(vdd, vbb_active)) * 1e-3;
    let leak_idle_w = tech.leakage_mw(cost.area_mm2, OperatingPoint::new(vdd, vbb_idle)) * 1e-3;
    let e_op_j = cost.dyn_energy_pj(vdd, 1.0) * 1e-12;

    let mut ops = 0u64;
    let mut dynamic = 0.0f64;
    let mut leakage = 0.0f64;
    let mut transition = 0.0f64;
    for seg in &profile.segments {
        let dur_s = seg.cycles as f64 * cycle_s;
        if seg.active {
            ops += seg.cycles;
            dynamic += seg.cycles as f64 * e_op_j;
            leakage += leak_active_w * dur_s;
        } else if seg.cycles <= 2 * settle {
            // Idle gap too short to re-bias: leak at the active level.
            leakage += leak_active_w * dur_s;
        } else {
            // Down-transition (detect + settle) and up-transition each
            // leak at the high-bias level for `settle` cycles.
            let settle_s = settle as f64 * cycle_s;
            transition += 2.0 * leak_active_w * settle_s;
            let low_s = (seg.cycles - 2 * settle) as f64 * cycle_s;
            leakage += leak_idle_w * low_s;
        }
    }
    let total = dynamic + leakage + transition;
    Some(BbRunEnergy {
        ops,
        dynamic_pj: dynamic * 1e12,
        leakage_pj: leakage * 1e12,
        transition_pj: transition * 1e12,
        pj_per_op: if ops > 0 { total * 1e12 / ops as f64 } else { f64::INFINITY },
    })
}

/// The Fig. 4 blow-up factor: energy/op of a profile relative to the
/// 100%-utilization baseline under the same static nominal bias.
pub fn blowup_vs_full(
    unit: &FpuUnit,
    tech: &Technology,
    vdd: f64,
    policy: BbPolicy,
    profile: &UtilizationProfile,
) -> Option<f64> {
    let full = run_energy(
        unit,
        tech,
        vdd,
        BbPolicy::static_nominal(),
        &UtilizationProfile::full(profile.active_cycles().max(1)),
    )?;
    let run = run_energy(unit, tech, vdd, policy, profile)?;
    Some(run.pj_per_op / full.pj_per_op)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::generator::FpuConfig;

    fn setup() -> (FpuUnit, Technology) {
        (FpuUnit::generate(&FpuConfig::sp_cma()), Technology::fdsoi28())
    }

    fn ten_pct(cycles: u64) -> UtilizationProfile {
        // 10% utilization in 10k-cycle bursts (≈7 µs idle gaps: long
        // enough for the adaptive policy to re-bias).
        UtilizationProfile::duty(0.1, 10_000, cycles)
    }

    #[test]
    fn full_utilization_matches_power_model() {
        let (unit, tech) = setup();
        let r = run_energy(&unit, &tech, 0.8, BbPolicy::static_nominal(),
                           &UtilizationProfile::full(100_000)).unwrap();
        let eff = crate::energy::power::evaluate(
            &unit, &tech, OperatingPoint::new(0.8, 1.2), 1.0).unwrap();
        // pJ/op = 2 × pJ/FLOP.
        assert!((r.pj_per_op / (2.0 * eff.pj_per_flop) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn static_low_utilization_blows_up_2_to_3x() {
        // Fig. 4: "using the same VDD and Vt as the 100% activity core …
        // increases the energy/op by 3x" (at the energy-efficient
        // operating voltage, where leakage looms largest).
        let (unit, tech) = setup();
        let b = blowup_vs_full(&unit, &tech, 0.6, BbPolicy::static_nominal(),
                               &ten_pct(1_000_000)).unwrap();
        assert!((2.0..3.8).contains(&b), "static blow-up {b:.2}×");
    }

    #[test]
    fn adaptive_recovers_to_about_1_5x() {
        let (unit, tech) = setup();
        let freq = timing::timing(&unit.config, &tech, OperatingPoint::new(0.6, 1.2))
            .unwrap()
            .freq_ghz;
        let b = blowup_vs_full(&unit, &tech, 0.6, BbPolicy::adaptive_nominal(freq),
                               &ten_pct(1_000_000)).unwrap();
        assert!((1.05..1.9).contains(&b), "adaptive blow-up {b:.2}×");
    }

    #[test]
    fn adaptive_beats_static_at_low_utilization() {
        let (unit, tech) = setup();
        let freq = 1.0;
        for vdd in [0.55, 0.7, 0.9] {
            let s = blowup_vs_full(&unit, &tech, vdd, BbPolicy::static_nominal(),
                                   &ten_pct(500_000)).unwrap();
            let a = blowup_vs_full(&unit, &tech, vdd, BbPolicy::adaptive_nominal(freq),
                                   &ten_pct(500_000)).unwrap();
            assert!(a < s, "vdd {vdd}: adaptive {a:.2} vs static {s:.2}");
        }
    }

    #[test]
    fn short_gaps_defeat_adaptation() {
        // Idle gaps shorter than 2× settle leave the adaptive policy at
        // the static energy (no transition is attempted).
        let (unit, tech) = setup();
        let profile = UtilizationProfile::duty(0.1, 50, 100_000); // 450-cycle gaps
        let adaptive = BbPolicy::Adaptive { vbb_active: 1.2, vbb_idle: 0.0, settle_cycles: 1000 };
        let a = run_energy(&unit, &tech, 0.7, adaptive, &profile).unwrap();
        let s = run_energy(&unit, &tech, 0.7, BbPolicy::static_nominal(), &profile).unwrap();
        assert!((a.pj_per_op / s.pj_per_op - 1.0).abs() < 1e-9);
        assert_eq!(a.transition_pj, 0.0);
    }

    #[test]
    fn reverse_idle_bias_cuts_leakage_further() {
        let (unit, tech) = setup();
        let prof = ten_pct(1_000_000);
        let zero = BbPolicy::Adaptive { vbb_active: 1.2, vbb_idle: 0.0, settle_cycles: 1000 };
        let rev = BbPolicy::Adaptive { vbb_active: 1.2, vbb_idle: -1.0, settle_cycles: 1000 };
        let ez = run_energy(&unit, &tech, 0.7, zero, &prof).unwrap();
        let er = run_energy(&unit, &tech, 0.7, rev, &prof).unwrap();
        assert!(er.leakage_pj < ez.leakage_pj);
        assert!(er.pj_per_op < ez.pj_per_op);
    }

    #[test]
    fn transition_energy_scales_with_wakeups() {
        let (unit, tech) = setup();
        let few = UtilizationProfile::duty(0.1, 50_000, 1_000_000);
        let many = UtilizationProfile::duty(0.1, 5_000, 1_000_000);
        let pol = BbPolicy::Adaptive { vbb_active: 1.2, vbb_idle: 0.0, settle_cycles: 500 };
        let ef = run_energy(&unit, &tech, 0.7, pol, &few).unwrap();
        let em = run_energy(&unit, &tech, 0.7, pol, &many).unwrap();
        assert!(em.transition_pj > 2.0 * ef.transition_pj);
    }
}
