//! Body-bias control policies — the paper's second headline result.
//!
//! Fig. 4's experiment: a latency unit running a low-utilization
//! workload with the body bias **statically** set for speed (forward
//! bias, low V_t) leaks so much during the idle gaps that energy/op
//! rises ~3×. **Dynamically adapting** V_BB — dropping to zero/reverse
//! bias in idle periods — recovers most of it (≈1.5×).
//!
//! The adaptive policy is not free: the back-gate wells are an RC load
//! charged by a bias generator, so a transition takes ~1 µs during
//! which the unit either waits (wake-up latency) or leaks at the old
//! V_t. Both costs are modelled; the controller only wins when idle
//! periods are long compared to the settle time, exactly as the paper's
//! "lowering BB for low-utilization period" phrasing implies.
//!
//! Since the engine grew time-resolved [`ActivityTrace`]s, the adaptive
//! policy consumes **measured** traces directly ([`run_energy_trace`]):
//! idle/low-occupancy windows trigger the bias drop with the modelled
//! settle cost, and each active window's dynamic energy is scaled by its
//! own measured toggle statistics instead of the run-level average. The
//! original [`UtilizationProfile`] path ([`run_energy`]) is a thin shim
//! over the same accounting core (a profile is just a trace with
//! synthetic occupancy — see [`ActivityTrace::from_profile`]), so the
//! Fig. 4 reproduction is unchanged.

use crate::arch::engine::{ActivityAccumulator, ActivityTrace, ActivityWindow};
use crate::arch::generator::{FpuUnit, StructureReport};
use crate::energy::components::{unit_cost, UnitCost};
use crate::energy::tech::{OperatingPoint, Technology};
use crate::timing;
use crate::workloads::utilization::UtilizationProfile;

/// A body-bias policy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum BbPolicy {
    /// V_BB fixed for the whole run (the "statically set BB" curves).
    Static { vbb: f64 },
    /// V_BB dropped to `vbb_idle` when an idle period is detected and
    /// restored on wake-up.
    Adaptive {
        vbb_active: f64,
        vbb_idle: f64,
        /// Bias settle time in cycles (≈1 µs × f); leakage stays at the
        /// *higher* of the two bias levels while settling, and detection
        /// lags idle onset by the same amount.
        settle_cycles: u64,
    },
}

impl BbPolicy {
    /// The paper's nominal static policy (1.2 V forward).
    pub fn static_nominal() -> BbPolicy {
        BbPolicy::Static { vbb: Technology::NOMINAL_VBB }
    }

    /// The paper's adaptive policy: full forward bias when busy, zero
    /// bias when idle, with a settle time derived from the clock.
    pub fn adaptive_nominal(freq_ghz: f64) -> BbPolicy {
        BbPolicy::Adaptive {
            vbb_active: Technology::NOMINAL_VBB,
            vbb_idle: 0.0,
            settle_cycles: (1.0e3 * freq_ghz) as u64, // ≈1 µs
        }
    }
}

/// Energy accounting for one run of a profile under one policy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BbRunEnergy {
    /// FMAC ops executed (one per active cycle).
    pub ops: u64,
    pub dynamic_pj: f64,
    pub leakage_pj: f64,
    /// Extra leakage burned in bias transitions.
    pub transition_pj: f64,
    /// Energy per op, pJ.
    pub pj_per_op: f64,
}

/// One run of the shared accounting core: a stretch of consecutive
/// active cycles (with a dynamic-energy activity scale) or of
/// consecutive idle cycles.
#[derive(Debug, Clone, Copy)]
struct ActivityRun {
    active: bool,
    cycles: u64,
    /// Data-activity scale of the active cycles' dynamic energy (1.0 =
    /// the calibrated average; see `ActivityAccumulator::activity_scale`).
    scale: f64,
}

/// The levels a policy resolves to: (active V_BB, idle V_BB, settle).
fn policy_levels(policy: BbPolicy) -> (f64, f64, u64) {
    match policy {
        BbPolicy::Static { vbb } => (vbb, vbb, 0),
        BbPolicy::Adaptive { vbb_active, vbb_idle, settle_cycles } => {
            (vbb_active, vbb_idle, settle_cycles)
        }
    }
}

/// The streaming form of the accounting core: push active/idle runs as
/// they arrive, read the totals at the end. Consecutive idle runs are
/// merged before the settle-time decision, so window-granular producers
/// see the same contiguous gaps a segment-granular profile would.
///
/// Both post-hoc entry points ([`run_energy`] / [`run_energy_trace`],
/// via [`energy_of_runs`]) and the live [`StreamingController`] drive
/// this exact state machine — same operations, same order, same floats —
/// which is what makes the streamed energies **bit-identical** to the
/// post-hoc ones rather than merely close.
struct EnergyIntegrator {
    cost: UnitCost,
    vdd: f64,
    settle: u64,
    cycle_s: f64,
    leak_active_w: f64,
    leak_idle_w: f64,
    ops: u64,
    dynamic: f64,
    leakage: f64,
    transition: f64,
    pending_idle: u64,
}

impl EnergyIntegrator {
    /// `None` when the unit cannot operate at `vdd` under the policy's
    /// active bias (timing infeasible).
    fn new(unit: &FpuUnit, tech: &Technology, vdd: f64, policy: BbPolicy) -> Option<Self> {
        let cost = unit_cost(unit);
        let (vbb_active, vbb_idle, settle) = policy_levels(policy);
        // Timing is set by the *active* operating point; the unit never
        // computes under idle bias.
        let t = timing::timing(&unit.config, tech, OperatingPoint::new(vdd, vbb_active))?;
        let cycle_s = t.cycle_ps * 1e-12;
        let leak_active_w =
            tech.leakage_mw(cost.area_mm2, OperatingPoint::new(vdd, vbb_active)) * 1e-3;
        let leak_idle_w =
            tech.leakage_mw(cost.area_mm2, OperatingPoint::new(vdd, vbb_idle)) * 1e-3;
        Some(EnergyIntegrator {
            cost,
            vdd,
            settle,
            cycle_s,
            leak_active_w,
            leak_idle_w,
            ops: 0,
            dynamic: 0.0,
            leakage: 0.0,
            transition: 0.0,
            pending_idle: 0,
        })
    }

    /// Account the pending contiguous idle gap under the settle-time
    /// rule.
    fn flush_gap(&mut self) {
        let gap = self.pending_idle;
        self.pending_idle = 0;
        if gap == 0 {
            return;
        }
        if gap <= 2 * self.settle {
            // Idle gap too short to re-bias: leak at the active level.
            self.leakage += self.leak_active_w * (gap as f64 * self.cycle_s);
        } else {
            // Down-transition (detect + settle) and up-transition each
            // leak at the high-bias level for `settle` cycles.
            let settle_s = self.settle as f64 * self.cycle_s;
            self.transition += 2.0 * self.leak_active_w * settle_s;
            let low_s = (gap - 2 * self.settle) as f64 * self.cycle_s;
            self.leakage += self.leak_idle_w * low_s;
        }
    }

    fn push_run(&mut self, run: ActivityRun) {
        if run.active {
            self.flush_gap();
            self.ops += run.cycles;
            self.dynamic +=
                run.cycles as f64 * (self.cost.dyn_energy_pj(self.vdd, run.scale) * 1e-12);
            self.leakage += self.leak_active_w * (run.cycles as f64 * self.cycle_s);
        } else {
            self.pending_idle += run.cycles;
        }
    }

    fn finish(&mut self) -> BbRunEnergy {
        self.flush_gap();
        let total = self.dynamic + self.leakage + self.transition;
        BbRunEnergy {
            ops: self.ops,
            dynamic_pj: self.dynamic * 1e12,
            leakage_pj: self.leakage * 1e12,
            transition_pj: self.transition * 1e12,
            pj_per_op: if self.ops > 0 {
                total * 1e12 / self.ops as f64
            } else {
                f64::INFINITY
            },
        }
    }

    /// Non-destructive snapshot of energy/op over everything pushed so
    /// far. The still-open idle gap is charged at the **active** leakage
    /// level — its re-bias decision hasn't been made yet, so the
    /// snapshot is conservative and converges onto `finish()` whenever
    /// the gap closes. `INFINITY` before the first active cycle.
    fn live_pj_per_op(&self) -> f64 {
        if self.ops == 0 {
            return f64::INFINITY;
        }
        let pending = self.leak_active_w * (self.pending_idle as f64 * self.cycle_s);
        (self.dynamic + self.leakage + self.transition + pending) * 1e12 / self.ops as f64
    }
}

/// The accounting core shared by the profile path and the trace path —
/// a thin driver over [`EnergyIntegrator`].
fn energy_of_runs(
    unit: &FpuUnit,
    tech: &Technology,
    vdd: f64,
    policy: BbPolicy,
    runs: impl Iterator<Item = ActivityRun>,
) -> Option<BbRunEnergy> {
    let mut acc = EnergyIntegrator::new(unit, tech, vdd, policy)?;
    for run in runs {
        acc.push_run(run);
    }
    Some(acc.finish())
}

/// Simulate the energy of running `profile` on `unit` at `vdd` under a
/// bias policy. The unit issues one FMAC per active cycle (the Fig. 4
/// latency units are kept fed during bursts) and is clock-gated when
/// idle. This is the synthetic-occupancy shim over the same accounting
/// core [`run_energy_trace`] uses (activity scale pinned at the
/// calibrated 1.0), so the Fig. 4 reproduction is unchanged.
pub fn run_energy(
    unit: &FpuUnit,
    tech: &Technology,
    vdd: f64,
    policy: BbPolicy,
    profile: &UtilizationProfile,
) -> Option<BbRunEnergy> {
    let runs = profile
        .segments
        .iter()
        .map(|s| ActivityRun { active: s.active, cycles: s.cycles, scale: 1.0 });
    energy_of_runs(unit, tech, vdd, policy, runs)
}

/// Simulate the energy of a **measured** time-resolved trace under a
/// bias policy — the phase-aware path. Each window contributes its ops
/// as active cycles whose dynamic energy is scaled by the window's own
/// measured activity, and its unoccupied slots as idle cycles;
/// consecutive idle windows form the contiguous gaps the adaptive
/// policy's settle-time decision sees. A trace converted from a profile
/// with segment-aligned windows reproduces [`run_energy`] to float
/// round-off.
pub fn run_energy_trace(
    unit: &FpuUnit,
    tech: &Technology,
    vdd: f64,
    policy: BbPolicy,
    trace: &ActivityTrace,
) -> Option<BbRunEnergy> {
    let s = unit.structure();
    let runs = trace.windows().iter().flat_map(|w| {
        let ops = w.acc.ops;
        let idle = w.slots.saturating_sub(ops);
        let active_run = (ops > 0).then(|| ActivityRun {
            active: true,
            cycles: ops,
            scale: w.acc.activity_scale(s),
        });
        let idle_run = (idle > 0).then(|| ActivityRun { active: false, cycles: idle, scale: 1.0 });
        [active_run, idle_run].into_iter().flatten()
    });
    energy_of_runs(unit, tech, vdd, policy, runs)
}

/// Fleet-level merge of independently accounted energy runs — the
/// multi-stream counterpart of the per-shard accounting.
///
/// Each serve shard runs its own [`StreamingController`] over its own
/// window stream (its numbers stay bit-identical to that shard's
/// post-hoc [`run_energy_trace`] pass — nothing here touches them); the
/// fleet total is the exact sum of the per-run ops and energy terms,
/// with `pj_per_op` recomputed over the merged totals. Streams from
/// different units at different operating points merge soundly because
/// every term is already absolute energy, not a rate.
pub fn merge_run_energies<'a, I>(runs: I) -> BbRunEnergy
where
    I: IntoIterator<Item = &'a BbRunEnergy>,
{
    let mut ops = 0u64;
    let mut dynamic_pj = 0.0f64;
    let mut leakage_pj = 0.0f64;
    let mut transition_pj = 0.0f64;
    for r in runs {
        ops += r.ops;
        dynamic_pj += r.dynamic_pj;
        leakage_pj += r.leakage_pj;
        transition_pj += r.transition_pj;
    }
    BbRunEnergy {
        ops,
        dynamic_pj,
        leakage_pj,
        transition_pj,
        pj_per_op: if ops > 0 {
            (dynamic_pj + leakage_pj + transition_pj) / ops as f64
        } else {
            f64::INFINITY
        },
    }
}

/// The per-window V_BB schedule a policy produces on a trace — the
/// controller's decision sequence, consumable by
/// [`crate::energy::power::evaluate_windowed`] for window-granular power
/// integration. Fully-idle windows deep enough inside a long gap (≥ one
/// settle time from both edges, in a gap longer than two settle times)
/// sit at the idle bias; everything else stays at the active bias.
pub fn window_bias_schedule(policy: BbPolicy, trace: &ActivityTrace) -> Vec<f64> {
    let (vbb_active, vbb_idle, settle) = policy_levels(policy);
    let windows = trace.windows();
    let mut vbb = vec![vbb_active; windows.len()];
    let mut i = 0;
    while i < windows.len() {
        if windows[i].acc.ops > 0 {
            i += 1;
            continue;
        }
        // Contiguous run of fully-idle windows [i, j).
        let mut j = i;
        let mut gap = 0u64;
        while j < windows.len() && windows[j].acc.ops == 0 {
            gap += windows[j].slots;
            j += 1;
        }
        if gap > 2 * settle {
            let mut off = 0u64;
            for (w, slot) in vbb[i..j].iter_mut().zip(&windows[i..j]) {
                let end = off + slot.slots;
                if off >= settle && end <= gap - settle {
                    *w = vbb_idle;
                }
                off = end;
            }
        }
        i = j;
    }
    vbb
}

/// Outcome of a streamed body-bias control run ([`StreamingController`]).
#[derive(Debug, Clone, PartialEq)]
pub struct StreamedBb {
    /// Per-received-window bias decisions, in arrival order — the live
    /// counterpart of [`window_bias_schedule`]. On the same window
    /// sequence the two are bit-identical.
    pub schedule: Vec<f64>,
    /// Energy accounting over everything received — bit-identical to
    /// [`run_energy_trace`] on the same window sequence.
    pub energy: BbRunEnergy,
    /// Windows received (after any ring-overflow coalescing upstream).
    pub windows: u64,
    /// Ops received. Never drops, even when the feeding ring overflowed:
    /// coalesced windows carry their merged occupancy and toggle sums.
    pub ops: u64,
    /// Aggregate activity received — equals the producing trace's
    /// [`ActivityTrace::aggregate`] bit for bit, overflow or not.
    pub aggregate: ActivityAccumulator,
}

/// The **live** body-bias controller: consumes [`ActivityWindow`]s as
/// the engine publishes them (typically off a
/// [`crate::arch::engine::window_ring`] fed by the serve dispatcher) and
/// emits the bias decision per window plus running energy accounting —
/// re-biasing *during* a run instead of scoring it afterwards.
///
/// Guarantee (pinned by tests and asserted per serve run): on the same
/// window sequence, [`StreamingController::finish`] returns a schedule
/// bit-identical to [`window_bias_schedule`] and energies bit-identical
/// to [`run_energy_trace`]. Both follow from construction — the idle-gap
/// decision is deferred exactly until the gap closes (an active window
/// arrives or the stream ends), which is the same information horizon
/// the post-hoc pass has, and the energy side shares the post-hoc
/// [`EnergyIntegrator`] state machine verbatim.
///
/// A window merged by ring overflow is pushed like any other: its
/// occupancy and activity sums are intact (energy accounting never
/// drops), only the sub-window idle structure has degraded to the merged
/// window's occupancy — the documented overflow behavior.
pub struct StreamingController {
    vbb_active: f64,
    vbb_idle: f64,
    settle: u64,
    structure: StructureReport,
    integrator: EnergyIntegrator,
    schedule: Vec<f64>,
    /// Slot widths of the contiguous fully-idle windows whose bias
    /// decision is still open.
    pending_idle: Vec<u64>,
    windows: u64,
    ops: u64,
    aggregate: ActivityAccumulator,
}

impl StreamingController {
    /// `None` when the unit cannot operate at `vdd` under the policy's
    /// active bias.
    pub fn new(
        unit: &FpuUnit,
        tech: &Technology,
        vdd: f64,
        policy: BbPolicy,
    ) -> Option<StreamingController> {
        let (vbb_active, vbb_idle, settle) = policy_levels(policy);
        Some(StreamingController {
            vbb_active,
            vbb_idle,
            settle,
            structure: *unit.structure(),
            integrator: EnergyIntegrator::new(unit, tech, vdd, policy)?,
            schedule: Vec::new(),
            pending_idle: Vec::new(),
            windows: 0,
            ops: 0,
            aggregate: ActivityAccumulator::default(),
        })
    }

    /// Decide the pending idle gap: interior windows ≥ one settle time
    /// from both edges of a gap longer than two settle times drop to the
    /// idle bias — the same rule, in the same arithmetic, as
    /// [`window_bias_schedule`].
    fn flush_idle_gap(&mut self) {
        if self.pending_idle.is_empty() {
            return;
        }
        let gap: u64 = self.pending_idle.iter().sum();
        let deep = gap > 2 * self.settle;
        let mut off = 0u64;
        for &slots in &self.pending_idle {
            let end = off + slots;
            let vbb = if deep && off >= self.settle && end <= gap - self.settle {
                self.vbb_idle
            } else {
                self.vbb_active
            };
            self.schedule.push(vbb);
            off = end;
        }
        self.pending_idle.clear();
    }

    /// Consume one published window.
    pub fn push_window(&mut self, w: &ActivityWindow) {
        self.windows += 1;
        self.ops += w.acc.ops;
        self.aggregate.merge(&w.acc);
        // Energy: the same per-window decomposition as `run_energy_trace`
        // (active ops at the window's own measured activity scale, then
        // the unoccupied slots as idle cycles).
        let ops = w.acc.ops;
        let idle = w.slots.saturating_sub(ops);
        if ops > 0 {
            self.integrator.push_run(ActivityRun {
                active: true,
                cycles: ops,
                scale: w.acc.activity_scale(&self.structure),
            });
        }
        if idle > 0 {
            self.integrator.push_run(ActivityRun { active: false, cycles: idle, scale: 1.0 });
        }
        // Schedule: an active window closes (and decides) any open idle
        // gap and itself sits at the active bias; a fully-idle window
        // joins the open gap.
        if w.acc.ops > 0 {
            self.flush_idle_gap();
            self.schedule.push(self.vbb_active);
        } else {
            self.pending_idle.push(w.slots);
        }
    }

    /// Live energy/op over everything received so far — the streamed
    /// feedback signal an energy-aware router reads **mid-run**, without
    /// consuming the controller. Open idle gaps are charged at the
    /// active leakage level until their re-bias decision is made, so the
    /// snapshot never understates the eventual accounting of a gap that
    /// later drops to the idle bias. `INFINITY` until the first active
    /// window arrives.
    pub fn live_pj_per_op(&self) -> f64 {
        self.integrator.live_pj_per_op()
    }

    /// End of stream: decide any open idle gap and return the schedule
    /// and totals.
    pub fn finish(mut self) -> StreamedBb {
        self.flush_idle_gap();
        let energy = self.integrator.finish();
        StreamedBb {
            schedule: self.schedule,
            energy,
            windows: self.windows,
            ops: self.ops,
            aggregate: self.aggregate,
        }
    }
}

/// The Fig. 4 blow-up factor: energy/op of a profile relative to the
/// 100%-utilization baseline under the same static nominal bias.
pub fn blowup_vs_full(
    unit: &FpuUnit,
    tech: &Technology,
    vdd: f64,
    policy: BbPolicy,
    profile: &UtilizationProfile,
) -> Option<f64> {
    let full = run_energy(
        unit,
        tech,
        vdd,
        BbPolicy::static_nominal(),
        &UtilizationProfile::full(profile.active_cycles().max(1)),
    )?;
    let run = run_energy(unit, tech, vdd, policy, profile)?;
    Some(run.pj_per_op / full.pj_per_op)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::generator::FpuConfig;

    fn setup() -> (FpuUnit, Technology) {
        (FpuUnit::generate(&FpuConfig::sp_cma()), Technology::fdsoi28())
    }

    fn ten_pct(cycles: u64) -> UtilizationProfile {
        // 10% utilization in 10k-cycle bursts (≈7 µs idle gaps: long
        // enough for the adaptive policy to re-bias).
        UtilizationProfile::duty(0.1, 10_000, cycles)
    }

    #[test]
    fn full_utilization_matches_power_model() {
        let (unit, tech) = setup();
        let r = run_energy(&unit, &tech, 0.8, BbPolicy::static_nominal(),
                           &UtilizationProfile::full(100_000)).unwrap();
        let eff = crate::energy::power::evaluate(
            &unit, &tech, OperatingPoint::new(0.8, 1.2), 1.0).unwrap();
        // pJ/op = 2 × pJ/FLOP.
        assert!((r.pj_per_op / (2.0 * eff.pj_per_flop) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn static_low_utilization_blows_up_2_to_3x() {
        // Fig. 4: "using the same VDD and Vt as the 100% activity core …
        // increases the energy/op by 3x" (at the energy-efficient
        // operating voltage, where leakage looms largest).
        let (unit, tech) = setup();
        let b = blowup_vs_full(&unit, &tech, 0.6, BbPolicy::static_nominal(),
                               &ten_pct(1_000_000)).unwrap();
        assert!((2.0..3.8).contains(&b), "static blow-up {b:.2}×");
    }

    #[test]
    fn adaptive_recovers_to_about_1_5x() {
        let (unit, tech) = setup();
        let freq = timing::timing(&unit.config, &tech, OperatingPoint::new(0.6, 1.2))
            .unwrap()
            .freq_ghz;
        let b = blowup_vs_full(&unit, &tech, 0.6, BbPolicy::adaptive_nominal(freq),
                               &ten_pct(1_000_000)).unwrap();
        assert!((1.05..1.9).contains(&b), "adaptive blow-up {b:.2}×");
    }

    #[test]
    fn adaptive_beats_static_at_low_utilization() {
        let (unit, tech) = setup();
        let freq = 1.0;
        for vdd in [0.55, 0.7, 0.9] {
            let s = blowup_vs_full(&unit, &tech, vdd, BbPolicy::static_nominal(),
                                   &ten_pct(500_000)).unwrap();
            let a = blowup_vs_full(&unit, &tech, vdd, BbPolicy::adaptive_nominal(freq),
                                   &ten_pct(500_000)).unwrap();
            assert!(a < s, "vdd {vdd}: adaptive {a:.2} vs static {s:.2}");
        }
    }

    #[test]
    fn short_gaps_defeat_adaptation() {
        // Idle gaps shorter than 2× settle leave the adaptive policy at
        // the static energy (no transition is attempted).
        let (unit, tech) = setup();
        let profile = UtilizationProfile::duty(0.1, 50, 100_000); // 450-cycle gaps
        let adaptive = BbPolicy::Adaptive { vbb_active: 1.2, vbb_idle: 0.0, settle_cycles: 1000 };
        let a = run_energy(&unit, &tech, 0.7, adaptive, &profile).unwrap();
        let s = run_energy(&unit, &tech, 0.7, BbPolicy::static_nominal(), &profile).unwrap();
        assert!((a.pj_per_op / s.pj_per_op - 1.0).abs() < 1e-9);
        assert_eq!(a.transition_pj, 0.0);
    }

    #[test]
    fn reverse_idle_bias_cuts_leakage_further() {
        let (unit, tech) = setup();
        let prof = ten_pct(1_000_000);
        let zero = BbPolicy::Adaptive { vbb_active: 1.2, vbb_idle: 0.0, settle_cycles: 1000 };
        let rev = BbPolicy::Adaptive { vbb_active: 1.2, vbb_idle: -1.0, settle_cycles: 1000 };
        let ez = run_energy(&unit, &tech, 0.7, zero, &prof).unwrap();
        let er = run_energy(&unit, &tech, 0.7, rev, &prof).unwrap();
        assert!(er.leakage_pj < ez.leakage_pj);
        assert!(er.pj_per_op < ez.pj_per_op);
    }

    #[test]
    fn adaptive_on_full_activity_trace_equals_static() {
        // Satellite property (b): a 100%-activity trace has no idle
        // windows, so the adaptive policy never diverges from static —
        // the energies must be *identical*, not merely close.
        let (unit, tech) = setup();
        let trace = ActivityTrace::from_profile(&UtilizationProfile::full(200_000), 1_000);
        let adaptive = BbPolicy::Adaptive { vbb_active: 1.2, vbb_idle: 0.0, settle_cycles: 1_000 };
        let a = run_energy_trace(&unit, &tech, 0.7, adaptive, &trace).unwrap();
        let s = run_energy_trace(&unit, &tech, 0.7, BbPolicy::static_nominal(), &trace).unwrap();
        assert_eq!(a.pj_per_op, s.pj_per_op);
        assert_eq!(a.dynamic_pj, s.dynamic_pj);
        assert_eq!(a.leakage_pj, s.leakage_pj);
        assert_eq!(a.transition_pj, 0.0);
        // And a *measured* full-occupancy trace obeys the same identity.
        use crate::arch::engine::WordUnit;
        use crate::workloads::throughput::{OperandMix, OperandStream};
        let word = WordUnit::of(&unit);
        let mut stream = OperandStream::new(unit.config.precision, OperandMix::Finite, 11);
        let measured = ActivityTrace::record_profile(
            &word,
            &UtilizationProfile::full(20_000),
            512,
            &mut stream,
        );
        let am = run_energy_trace(&unit, &tech, 0.7, adaptive, &measured).unwrap();
        let sm =
            run_energy_trace(&unit, &tech, 0.7, BbPolicy::static_nominal(), &measured).unwrap();
        assert_eq!(am.pj_per_op, sm.pj_per_op);
        assert_eq!(am.transition_pj, 0.0);
    }

    #[test]
    fn trace_path_reproduces_profile_path_on_aligned_windows() {
        // The shim guarantee: a profile converted to a trace with
        // segment-aligned windows must reproduce the profile-based
        // energies (static and adaptive) to float round-off.
        let (unit, tech) = setup();
        let profile = ten_pct(1_000_000); // 10k bursts, 90k gaps
        let trace = ActivityTrace::from_profile(&profile, 1_000); // divides both
        for policy in [
            BbPolicy::static_nominal(),
            BbPolicy::Adaptive { vbb_active: 1.2, vbb_idle: 0.0, settle_cycles: 1_000 },
            BbPolicy::Adaptive { vbb_active: 1.2, vbb_idle: -1.0, settle_cycles: 500 },
        ] {
            let p = run_energy(&unit, &tech, 0.6, policy, &profile).unwrap();
            let t = run_energy_trace(&unit, &tech, 0.6, policy, &trace).unwrap();
            assert_eq!(p.ops, t.ops);
            assert!((t.pj_per_op / p.pj_per_op - 1.0).abs() < 1e-9, "{policy:?}");
            assert!((t.transition_pj - p.transition_pj).abs() <= 1e-9 * p.transition_pj.max(1.0));
        }
    }

    #[test]
    fn window_bias_schedule_drops_only_deep_idle_windows() {
        // 2 active windows, 8 idle, 2 active — window 100 slots,
        // settle 150 ⇒ the first/last ~2 idle windows keep the active
        // bias (settling), the interior drops.
        let profile = UtilizationProfile {
            name: "t".into(),
            segments: vec![
                crate::workloads::utilization::Segment { active: true, cycles: 200 },
                crate::workloads::utilization::Segment { active: false, cycles: 800 },
                crate::workloads::utilization::Segment { active: true, cycles: 200 },
            ],
        };
        let trace = ActivityTrace::from_profile(&profile, 100);
        let pol = BbPolicy::Adaptive { vbb_active: 1.2, vbb_idle: 0.0, settle_cycles: 150 };
        let vbb = window_bias_schedule(pol, &trace);
        assert_eq!(vbb.len(), trace.len());
        // Active windows (0,1 and 10,11) at the active bias.
        assert_eq!(vbb[0], 1.2);
        assert_eq!(vbb[1], 1.2);
        assert_eq!(vbb[10], 1.2);
        assert_eq!(vbb[11], 1.2);
        // Gap windows: 2,3 settle down; 4..=7 idle; 8,9 settle up.
        assert_eq!(vbb[2], 1.2);
        assert_eq!(vbb[3], 1.2);
        for w in 4..=7 {
            assert_eq!(vbb[w], 0.0, "window {w}");
        }
        assert_eq!(vbb[8], 1.2);
        assert_eq!(vbb[9], 1.2);
        // A short gap (≤ 2·settle) never drops.
        let short = UtilizationProfile::duty(0.5, 100, 10_000);
        let strace = ActivityTrace::from_profile(&short, 100);
        let pol2 = BbPolicy::Adaptive { vbb_active: 1.2, vbb_idle: 0.0, settle_cycles: 100 };
        assert!(window_bias_schedule(pol2, &strace).iter().all(|&v| v == 1.2));
        // Static schedules are flat.
        assert!(window_bias_schedule(BbPolicy::static_nominal(), &trace)
            .iter()
            .all(|&v| v == Technology::NOMINAL_VBB));
    }

    #[test]
    fn measured_trace_adaptive_beats_static_at_low_occupancy() {
        // The phase-aware payoff on a *measured* trace: word-level
        // execution woven into the Fig. 4 10% duty profile.
        use crate::arch::engine::WordUnit;
        use crate::workloads::throughput::{OperandMix, OperandStream};
        let (unit, tech) = setup();
        let word = WordUnit::of(&unit);
        let mut stream = OperandStream::new(unit.config.precision, OperandMix::Finite, 23);
        let trace = ActivityTrace::record_profile(
            &word,
            &UtilizationProfile::duty(0.1, 10_000, 200_000),
            1_000,
            &mut stream,
        );
        let freq = timing::timing(&unit.config, &tech, OperatingPoint::new(0.6, 1.2))
            .unwrap()
            .freq_ghz;
        let s =
            run_energy_trace(&unit, &tech, 0.6, BbPolicy::static_nominal(), &trace).unwrap();
        let a =
            run_energy_trace(&unit, &tech, 0.6, BbPolicy::adaptive_nominal(freq), &trace).unwrap();
        assert_eq!(s.ops, 20_000);
        assert!(a.pj_per_op < s.pj_per_op, "adaptive {} vs static {}", a.pj_per_op, s.pj_per_op);
        assert!(a.transition_pj > 0.0);
    }

    #[test]
    fn streaming_controller_matches_posthoc_bit_for_bit() {
        // The live controller's contract: pushing a trace's windows one
        // at a time yields the SAME schedule as window_bias_schedule and
        // the SAME energies as run_energy_trace — bit-for-bit equality,
        // not tolerance — on synthetic and measured traces, under
        // static and adaptive policies.
        use crate::arch::engine::WordUnit;
        use crate::workloads::throughput::{OperandMix, OperandStream};
        let (unit, tech) = setup();
        let synthetic = ActivityTrace::from_profile(&ten_pct(300_000), 1_000);
        let word = WordUnit::of(&unit);
        let mut stream = OperandStream::new(unit.config.precision, OperandMix::Finite, 17);
        let measured = ActivityTrace::record_profile(
            &word,
            &UtilizationProfile::duty(0.2, 5_000, 100_000),
            500,
            &mut stream,
        );
        for trace in [&synthetic, &measured] {
            for policy in [
                BbPolicy::static_nominal(),
                BbPolicy::Adaptive { vbb_active: 1.2, vbb_idle: 0.0, settle_cycles: 1_000 },
                BbPolicy::Adaptive { vbb_active: 1.2, vbb_idle: -1.0, settle_cycles: 500 },
            ] {
                let mut ctrl = StreamingController::new(&unit, &tech, 0.6, policy).unwrap();
                for w in trace.windows() {
                    ctrl.push_window(w);
                }
                let out = ctrl.finish();
                assert_eq!(out.schedule, window_bias_schedule(policy, trace), "{policy:?}");
                let want = run_energy_trace(&unit, &tech, 0.6, policy, trace).unwrap();
                assert_eq!(out.energy, want, "{policy:?}: streamed energy must be bit-identical");
                assert_eq!(out.windows, trace.len() as u64);
                assert_eq!(out.ops, trace.total_ops());
                assert_eq!(out.aggregate, trace.aggregate());
            }
        }
    }

    #[test]
    fn live_pj_snapshot_matches_finish_when_no_gap_is_open() {
        let (unit, tech) = setup();
        let profile = UtilizationProfile {
            name: "t".into(),
            segments: vec![
                crate::workloads::utilization::Segment { active: true, cycles: 200 },
                crate::workloads::utilization::Segment { active: false, cycles: 800 },
                crate::workloads::utilization::Segment { active: true, cycles: 200 },
            ],
        };
        let trace = ActivityTrace::from_profile(&profile, 100);
        let policy = BbPolicy::Adaptive { vbb_active: 1.2, vbb_idle: 0.0, settle_cycles: 150 };
        let mut ctrl = StreamingController::new(&unit, &tech, 0.6, policy).unwrap();
        assert!(ctrl.live_pj_per_op().is_infinite(), "undefined before the first op");
        let mut snapshots = Vec::new();
        for w in trace.windows() {
            ctrl.push_window(w);
            snapshots.push(ctrl.live_pj_per_op());
        }
        assert!(snapshots.iter().all(|v| v.is_finite()));
        let final_snapshot = *snapshots.last().unwrap();
        let out = ctrl.finish();
        // The trace ends on an active window, so no idle gap is open
        // and the snapshot equals the finished accounting exactly.
        assert_eq!(final_snapshot, out.energy.pj_per_op);
        // Mid-gap (window 5 sits deep in the 800-cycle gap) the open
        // idle is charged at the active leakage level, so the snapshot
        // never understates the eventual re-biased accounting.
        assert!(snapshots[5] >= out.energy.pj_per_op);
    }

    #[test]
    fn streaming_controller_coalesced_stream_preserves_accounting() {
        // The ring-overflow degradation: neighbouring windows merged
        // into one. The controller's schedule then equals the post-hoc
        // schedule of the *merged* trace (it can only decide on what it
        // received), and — the satellite guarantee — no ops or activity
        // are ever dropped from the energy accounting.
        let (unit, tech) = setup();
        let trace = ActivityTrace::from_profile(&ten_pct(200_000), 500);
        let mut merged: Vec<ActivityWindow> = Vec::new();
        for (i, w) in trace.windows().iter().enumerate() {
            if i % 3 == 0 {
                merged.push(*w);
            } else {
                let last = merged.last_mut().unwrap();
                last.slots += w.slots;
                last.acc.merge(&w.acc);
            }
        }
        let merged_trace = ActivityTrace::from_raw_windows(500, merged);
        let policy =
            BbPolicy::Adaptive { vbb_active: 1.2, vbb_idle: 0.0, settle_cycles: 1_000 };
        let mut ctrl = StreamingController::new(&unit, &tech, 0.6, policy).unwrap();
        for w in merged_trace.windows() {
            ctrl.push_window(w);
        }
        let out = ctrl.finish();
        assert_eq!(out.schedule, window_bias_schedule(policy, &merged_trace));
        assert_eq!(out.energy, run_energy_trace(&unit, &tech, 0.6, policy, &merged_trace).unwrap());
        // Accounting preserved vs the ORIGINAL stream.
        assert_eq!(out.ops, trace.total_ops());
        assert_eq!(out.aggregate, trace.aggregate());
        let mut slots = 0u64;
        for w in merged_trace.windows() {
            slots += w.slots;
        }
        assert_eq!(slots, trace.total_slots());
        // Occupancy-only degradation is graceful, not free: the merged
        // windows still carry every idle slot, so total energy stays
        // finite and comparable (same ops, same dynamic term).
        let orig = run_energy_trace(&unit, &tech, 0.6, policy, &trace).unwrap();
        assert_eq!(out.energy.ops, orig.ops);
        assert!((out.energy.dynamic_pj - orig.dynamic_pj).abs() < 1e-9 * orig.dynamic_pj);
        let mut acc = ActivityAccumulator::default();
        acc.merge(&out.aggregate);
        assert_eq!(acc, trace.aggregate());
    }

    #[test]
    fn merge_run_energies_is_the_exact_sum() {
        let (unit, tech) = setup();
        let freq = 1.0;
        let a = run_energy(&unit, &tech, 0.7, BbPolicy::static_nominal(), &ten_pct(200_000))
            .unwrap();
        let b = run_energy(&unit, &tech, 0.6, BbPolicy::adaptive_nominal(freq), &ten_pct(500_000))
            .unwrap();
        let m = merge_run_energies([&a, &b]);
        assert_eq!(m.ops, a.ops + b.ops);
        assert_eq!(m.dynamic_pj, a.dynamic_pj + b.dynamic_pj);
        assert_eq!(m.leakage_pj, a.leakage_pj + b.leakage_pj);
        assert_eq!(m.transition_pj, a.transition_pj + b.transition_pj);
        let total = m.dynamic_pj + m.leakage_pj + m.transition_pj;
        assert!((m.pj_per_op - total / m.ops as f64).abs() < 1e-12 * m.pj_per_op.max(1.0));
        // A singleton merge keeps every term verbatim (pj_per_op is
        // recomputed from the pJ terms, so it agrees to round-off).
        let one = merge_run_energies([&a]);
        assert_eq!(one.ops, a.ops);
        assert_eq!(one.dynamic_pj, a.dynamic_pj);
        assert_eq!(one.leakage_pj, a.leakage_pj);
        assert_eq!(one.transition_pj, a.transition_pj);
        assert!((one.pj_per_op / a.pj_per_op - 1.0).abs() < 1e-12);
        // Empty merge: nothing ran, energy/op undefined.
        let none = merge_run_energies(std::iter::empty::<&BbRunEnergy>());
        assert_eq!(none.ops, 0);
        assert!(none.pj_per_op.is_infinite());
    }

    #[test]
    fn transition_energy_scales_with_wakeups() {
        let (unit, tech) = setup();
        let few = UtilizationProfile::duty(0.1, 50_000, 1_000_000);
        let many = UtilizationProfile::duty(0.1, 5_000, 1_000_000);
        let pol = BbPolicy::Adaptive { vbb_active: 1.2, vbb_idle: 0.0, settle_cycles: 500 };
        let ef = run_energy(&unit, &tech, 0.7, pol, &few).unwrap();
        let em = run_energy(&unit, &tech, 0.7, pol, &many).unwrap();
        assert!(em.transition_pj > 2.0 * ef.transition_pj);
    }
}
