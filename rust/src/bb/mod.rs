//! Body-bias controllers: static vs dynamically adaptive V_BB and the
//! low-utilization energy accounting behind Fig. 4. The adaptive policy
//! consumes measured [`crate::arch::engine::ActivityTrace`]s
//! ([`run_energy_trace`]); the synthetic-profile path ([`run_energy`])
//! is a shim over the same accounting core. [`StreamingController`]
//! consumes windows **live** off a ring buffer while the engine is
//! still executing — its schedule and energies are bit-identical to
//! the post-hoc [`window_bias_schedule`] / [`run_energy_trace`] pair on
//! the same window stream.

pub mod controller;

pub use controller::{
    blowup_vs_full, merge_run_energies, run_energy, run_energy_trace, window_bias_schedule,
    BbPolicy, BbRunEnergy, StreamedBb, StreamingController,
};
