//! Body-bias controllers: static vs dynamically adaptive V_BB and the
//! low-utilization energy accounting behind Fig. 4.

pub mod controller;

pub use controller::{blowup_vs_full, run_energy, BbPolicy, BbRunEnergy};
