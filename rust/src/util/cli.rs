//! Tiny command-line parser (clap is unavailable offline).
//!
//! Supports `binary SUBCOMMAND [--flag] [--key value]` — all the `fpmax`
//! CLI needs. Unknown flags are errors so typos fail loudly.

use std::collections::BTreeMap;

/// Parsed command line: one subcommand plus `--key value` / `--flag`
/// options.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    options: BTreeMap<String, String>,
    /// Options that were consumed by a lookup (for unknown-option checks).
    seen: std::cell::RefCell<std::collections::BTreeSet<String>>,
}

impl Args {
    /// Parse from an iterator of argument strings (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> crate::Result<Args> {
        let mut args = Args::default();
        let mut it = argv.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with("--") {
                args.subcommand = it.next();
            }
        }
        while let Some(a) = it.next() {
            let Some(key) = a.strip_prefix("--") else {
                anyhow::bail!("unexpected positional argument: {a}");
            };
            // `--key=value`, `--key value`, or bare `--flag`.
            if let Some((k, v)) = key.split_once('=') {
                args.options.insert(k.to_string(), v.to_string());
            } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                let v = it.next().unwrap();
                args.options.insert(key.to_string(), v);
            } else {
                args.options.insert(key.to_string(), "true".to_string());
            }
        }
        Ok(args)
    }

    /// Parse the process's own arguments.
    pub fn from_env() -> crate::Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    /// String option.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.seen.borrow_mut().insert(key.to_string());
        self.options.get(key).map(|s| s.as_str())
    }

    /// Boolean flag (present, `=true`, or `=1`).
    pub fn flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1"))
    }

    /// Typed option with default.
    pub fn get_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> crate::Result<T> {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key}: cannot parse {s:?} as {}", std::any::type_name::<T>())),
        }
    }

    /// Error on any option that was never consumed — catches typos.
    pub fn reject_unknown(&self) -> crate::Result<()> {
        let seen = self.seen.borrow();
        let unknown: Vec<&String> = self.options.keys().filter(|k| !seen.contains(*k)).collect();
        if unknown.is_empty() {
            Ok(())
        } else {
            anyhow::bail!("unknown option(s): {unknown:?}")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse(&["fig3", "--unit", "sp_fma", "--points=25", "--verbose"]);
        assert_eq!(a.subcommand.as_deref(), Some("fig3"));
        assert_eq!(a.get("unit"), Some("sp_fma"));
        assert_eq!(a.get_parse("points", 0u32).unwrap(), 25);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        assert!(a.reject_unknown().is_ok());
    }

    #[test]
    fn unknown_options_detected() {
        let a = parse(&["table1", "--oops", "3"]);
        assert_eq!(a.subcommand.as_deref(), Some("table1"));
        assert!(a.reject_unknown().is_err());
        let _ = a.get("oops");
        assert!(a.reject_unknown().is_ok());
    }

    #[test]
    fn default_values() {
        let a = parse(&["sweep"]);
        assert_eq!(a.get_parse("seed", 42u64).unwrap(), 42);
    }

    #[test]
    fn bad_parse_is_error() {
        let a = parse(&["x", "--n", "abc"]);
        assert!(a.get_parse("n", 0u32).is_err());
    }

    #[test]
    fn positional_after_subcommand_rejected() {
        assert!(Args::parse(["a".to_string(), "b".to_string()]).is_err());
    }
}
