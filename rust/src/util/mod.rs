//! Self-contained utilities: a deterministic PRNG, operand generators,
//! small statistics, and a property-test driver.
//!
//! The build environment is offline (no `rand`, no `proptest`, no
//! `criterion`), so the crate carries its own minimal versions. All
//! randomness in the repository flows through [`Rng`] with explicit
//! seeds — every experiment is bit-reproducible.

pub mod bench;
pub mod cli;
pub mod stats;

/// SplitMix64: tiny, fast, well-distributed; the de-facto seeding PRNG.
/// (Sebastiano Vigna, public domain reference implementation.)
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create from an explicit seed. Every consumer must pass one —
    /// there is deliberately no entropy-based constructor.
    pub fn new(seed: u64) -> Rng {
        Rng { state: seed }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e37_79b9_7f4a_7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }

    /// Next value in `[0, n)` (Lemire's multiply-shift reduction; the tiny
    /// modulo bias is irrelevant for workload generation).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli draw.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// A random finite f32 bit pattern with uniformly distributed
    /// exponent field — exercises subnormals and near-overflow values far
    /// more than uniform-bits sampling would.
    pub fn f32_operand(&mut self) -> u32 {
        let sign = (self.next_u64() & 1) as u32;
        let exp = self.below(255) as u32; // 0..=254: finite only
        let frac = (self.next_u64() & 0x7f_ffff) as u32;
        (sign << 31) | (exp << 23) | frac
    }

    /// A random finite f64 bit pattern with uniform exponent field.
    pub fn f64_operand(&mut self) -> u64 {
        let sign = self.next_u64() & 1;
        let exp = self.below(2047); // finite only
        let frac = self.next_u64() & ((1 << 52) - 1);
        (sign << 63) | (exp << 52) | frac
    }

    /// Any f32 bit pattern, including Inf/NaN (for robustness tests).
    pub fn f32_any(&mut self) -> u32 {
        self.next_u64() as u32
    }

    /// Any f64 bit pattern, including Inf/NaN.
    pub fn f64_any(&mut self) -> u64 {
        self.next_u64()
    }
}

/// Minimal property-test driver: run `f` on `n` generated cases, panic
/// with the seed and case index on the first failure so it can be
/// replayed exactly.
pub fn check_cases<G, T, F>(seed: u64, n: usize, mut generate: G, mut f: F)
where
    G: FnMut(&mut Rng) -> T,
    T: std::fmt::Debug,
    F: FnMut(&T) -> std::result::Result<(), String>,
{
    let mut rng = Rng::new(seed);
    for i in 0..n {
        let case = generate(&mut rng);
        if let Err(msg) = f(&case) {
            panic!("property failed (seed={seed}, case #{i}): {msg}\n  input: {case:?}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_distinct() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        let xs: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_eq!(xs, ys);
        let mut c = Rng::new(43);
        assert_ne!(xs[0], c.next_u64());
        // No short cycles in the window we care about.
        let mut seen = std::collections::HashSet::new();
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            assert!(seen.insert(r.next_u64()));
        }
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(1);
        let mut hist = [0u32; 10];
        for _ in 0..10_000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            hist[v] += 1;
        }
        // Roughly uniform: every bucket within ±30% of the mean.
        for (i, &h) in hist.iter().enumerate() {
            assert!((700..=1300).contains(&h), "bucket {i}: {h}");
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(2);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        assert!((sum / 10_000.0 - 0.5).abs() < 0.02);
    }

    #[test]
    fn operand_generators_finite() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            assert!(f32::from_bits(r.f32_operand()).is_finite());
            assert!(f64::from_bits(r.f64_operand()).is_finite());
        }
    }

    #[test]
    fn operand_exponent_spread() {
        // The stratified generator must hit subnormal (exp field 0) and
        // high-exponent (≥ 250) regions in 10k draws.
        let mut r = Rng::new(4);
        let (mut lo, mut hi) = (0, 0);
        for _ in 0..10_000 {
            let e = (r.f32_operand() >> 23) & 0xff;
            if e == 0 {
                lo += 1;
            }
            if e >= 250 {
                hi += 1;
            }
        }
        assert!(lo > 10, "subnormals undersampled: {lo}");
        assert!(hi > 50, "large exponents undersampled: {hi}");
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn check_cases_reports_failure() {
        check_cases(9, 100, |r| r.below(100), |&v| {
            if v < 90 {
                Ok(())
            } else {
                Err("too big".into())
            }
        });
    }
}
