//! Minimal benchmark harness (criterion is unavailable offline).
//!
//! [`BenchRunner`] provides warmup, repeated timed samples, and a stable
//! report format shared by every `cargo bench` target. Timing uses
//! `std::time::Instant`; a `black_box` re-export prevents the optimizer
//! from deleting measured work.

use std::time::Instant;

use super::stats::{summarize, Summary};

/// Re-export of the optimizer barrier.
pub use std::hint::black_box;

/// A simple time-per-iteration benchmark runner.
pub struct BenchRunner {
    /// Samples per benchmark.
    pub samples: usize,
    /// Warmup iterations before sampling.
    pub warmup_iters: usize,
    /// Iterations per sample (amortizes timer overhead).
    pub iters_per_sample: usize,
}

impl Default for BenchRunner {
    fn default() -> Self {
        BenchRunner { samples: 20, warmup_iters: 3, iters_per_sample: 1 }
    }
}

/// One benchmark's result.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    /// Seconds per iteration.
    pub per_iter: Summary,
    /// Optional throughput denominator (items per iteration).
    pub items_per_iter: Option<f64>,
}

impl BenchResult {
    /// items/second at the median, if a throughput denominator was set.
    pub fn throughput(&self) -> Option<f64> {
        self.items_per_iter.map(|n| n / self.per_iter.p50)
    }
}

impl BenchRunner {
    /// Quick-run configuration honouring `FPMAX_BENCH_FAST=1` (used by the
    /// test suite to smoke the bench targets).
    pub fn from_env() -> BenchRunner {
        if std::env::var("FPMAX_BENCH_FAST").as_deref() == Ok("1") {
            BenchRunner { samples: 3, warmup_iters: 1, iters_per_sample: 1 }
        } else {
            BenchRunner::default()
        }
    }

    /// Time `f`, which performs one logical iteration of `items` items.
    pub fn bench<F: FnMut()>(&self, name: &str, items: Option<f64>, mut f: F) -> BenchResult {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..self.iters_per_sample {
                f();
            }
            samples.push(t0.elapsed().as_secs_f64() / self.iters_per_sample as f64);
        }
        BenchResult {
            name: name.to_string(),
            per_iter: summarize(&samples),
            items_per_iter: items,
        }
    }

    /// Bench and print a one-line report.
    pub fn run<F: FnMut()>(&self, name: &str, items: Option<f64>, f: F) -> BenchResult {
        let r = self.bench(name, items, f);
        print_result(&r);
        r
    }
}

/// Print a result line in the shared format.
pub fn print_result(r: &BenchResult) {
    let tp = match r.throughput() {
        Some(t) if t >= 1e9 => format!("  {:8.2} Gitem/s", t / 1e9),
        Some(t) if t >= 1e6 => format!("  {:8.2} Mitem/s", t / 1e6),
        Some(t) if t >= 1e3 => format!("  {:8.2} kitem/s", t / 1e3),
        Some(t) => format!("  {t:8.2} item/s"),
        None => String::new(),
    };
    println!(
        "bench {:<44} {:>12} median  {:>12} p95{}",
        r.name,
        humanize(r.per_iter.p50),
        humanize(r.per_iter.p95),
        tp
    );
}

/// Human-readable seconds.
pub fn humanize(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2} ms", secs * 1e3)
    } else {
        format!("{secs:.3} s")
    }
}

/// Emit the standard bench header so every target's output is uniform.
pub fn header(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_work() {
        let r = BenchRunner { samples: 5, warmup_iters: 1, iters_per_sample: 2 }.bench(
            "spin",
            Some(1000.0),
            || {
                let mut acc = 0u64;
                for i in 0..1000u64 {
                    acc = acc.wrapping_add(black_box(i));
                }
                black_box(acc);
            },
        );
        assert_eq!(r.per_iter.n, 5);
        assert!(r.per_iter.p50 > 0.0);
        assert!(r.throughput().unwrap() > 0.0);
    }

    #[test]
    fn humanize_ranges() {
        assert!(humanize(3e-9).contains("ns"));
        assert!(humanize(3e-6).contains("µs"));
        assert!(humanize(3e-3).contains("ms"));
        assert!(humanize(3.0).contains("s"));
    }
}
