//! Small statistics helpers for benchmark reporting.

/// Summary statistics over a sample of f64 measurements.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
    pub stddev: f64,
}

/// Compute summary statistics. Panics on an empty slice.
pub fn summarize(samples: &[f64]) -> Summary {
    assert!(!samples.is_empty(), "no samples");
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).expect("NaN sample"));
    let n = sorted.len();
    let mean = sorted.iter().sum::<f64>() / n as f64;
    let var = sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
    Summary {
        n,
        mean,
        min: sorted[0],
        max: sorted[n - 1],
        p50: percentile(&sorted, 0.50),
        p95: percentile(&sorted, 0.95),
        stddev: var.sqrt(),
    }
}

/// Percentile by linear interpolation over a pre-sorted slice.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Geometric mean (used for cross-benchmark aggregation, like the paper's
/// SPEC FP averages).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let log_sum: f64 = xs.iter().map(|&x| {
        assert!(x > 0.0, "geomean needs positive values");
        x.ln()
    }).sum();
    (log_sum / xs.len() as f64).exp()
}

/// Relative difference |a−b| / max(|a|,|b|) — tolerance checks against the
/// paper's published numbers.
pub fn rel_diff(a: f64, b: f64) -> f64 {
    if a == b {
        return 0.0;
    }
    (a - b).abs() / a.abs().max(b.abs())
}

/// Exponentially-weighted moving average with an explicit observation
/// count, so an estimator can be carried across shard incarnations: a
/// respawned shard seeds its estimator from the dead incarnation's
/// `(value, count)` snapshot ([`Ewma::seeded`]) and keeps decaying from
/// there — the feedback router never restarts cold after a respawn.
///
/// `value()` is `None` until the first observation (warmup), so a
/// routing policy can distinguish "no signal yet" from "measured zero".
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Ewma {
    alpha: f64,
    value: f64,
    count: u64,
}

impl Ewma {
    /// A cold estimator. `alpha` in (0, 1]: the weight of each new
    /// observation (1.0 degenerates to "latest sample wins").
    pub fn new(alpha: f64) -> Ewma {
        assert!(alpha > 0.0 && alpha <= 1.0, "EWMA alpha must be in (0, 1], got {alpha}");
        Ewma { alpha, value: 0.0, count: 0 }
    }

    /// An estimator warm-started from a prior incarnation's snapshot.
    /// With `count == 0` this is identical to [`Ewma::new`].
    pub fn seeded(alpha: f64, value: f64, count: u64) -> Ewma {
        let mut e = Ewma::new(alpha);
        if count > 0 {
            e.value = value;
            e.count = count;
        }
        e
    }

    /// Fold one observation in. The first observation initializes the
    /// estimate exactly (no bias toward the zero default).
    pub fn observe(&mut self, x: f64) {
        if self.count == 0 {
            self.value = x;
        } else {
            self.value += self.alpha * (x - self.value);
        }
        self.count += 1;
    }

    /// Current estimate; `None` before the first observation.
    pub fn value(&self) -> Option<f64> {
        (self.count > 0).then_some(self.value)
    }

    /// Observations folded in, including any seeded-in prior count.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// The estimator's observation weight.
    pub fn alpha(&self) -> f64 {
        self.alpha
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
        assert!((s.stddev - 2.0f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let v = [0.0, 10.0];
        assert_eq!(percentile(&v, 0.0), 0.0);
        assert_eq!(percentile(&v, 1.0), 10.0);
        assert_eq!(percentile(&v, 0.25), 2.5);
        assert_eq!(percentile(&[7.0], 0.5), 7.0);
    }

    #[test]
    fn geomean_known() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn rel_diff_symmetric() {
        assert_eq!(rel_diff(1.0, 1.0), 0.0);
        assert!((rel_diff(90.0, 100.0) - 0.1).abs() < 1e-12);
        assert_eq!(rel_diff(100.0, 90.0), rel_diff(90.0, 100.0));
    }

    #[test]
    fn ewma_warmup_is_explicit() {
        // No value before the first observation; the first observation
        // becomes the estimate exactly (no pull toward zero).
        let mut e = Ewma::new(0.25);
        assert_eq!(e.value(), None);
        assert_eq!(e.count(), 0);
        e.observe(8.0);
        assert_eq!(e.value(), Some(8.0));
        assert_eq!(e.count(), 1);
    }

    #[test]
    fn ewma_decays_toward_the_input() {
        let mut e = Ewma::new(0.5);
        e.observe(0.0);
        for _ in 0..50 {
            e.observe(10.0);
        }
        let v = e.value().unwrap();
        assert!((v - 10.0).abs() < 1e-9, "converged to {v}");
        // One step from a known state is exactly alpha-weighted.
        let mut one = Ewma::new(0.25);
        one.observe(4.0);
        one.observe(8.0);
        assert_eq!(one.value(), Some(4.0 + 0.25 * 4.0));
        // A spike decays geometrically: each quiet step closes 1-alpha
        // of the remaining gap.
        let mut s = Ewma::new(0.25);
        s.observe(1.0);
        s.observe(100.0);
        let spike = s.value().unwrap();
        s.observe(1.0);
        let after = s.value().unwrap();
        assert!((after - 1.0) < (spike - 1.0) * 0.76);
    }

    #[test]
    fn ewma_seeded_continues_the_original_exactly() {
        // The merge-across-incarnation contract: snapshot (value, count)
        // from a live estimator, seed a fresh one, and both must track
        // identically from there on.
        let mut orig = Ewma::new(0.2);
        for x in [3.0, 7.0, 2.0, 9.0] {
            orig.observe(x);
        }
        let mut revived = Ewma::seeded(0.2, orig.value().unwrap(), orig.count());
        assert_eq!(revived.value(), orig.value());
        assert_eq!(revived.count(), orig.count());
        for x in [1.5, 8.25, 0.125] {
            orig.observe(x);
            revived.observe(x);
        }
        assert_eq!(revived.value(), orig.value());
        assert_eq!(revived.count(), orig.count());
        // Seeding with count 0 is a cold start, whatever the value says.
        let cold = Ewma::seeded(0.2, 123.0, 0);
        assert_eq!(cold.value(), None);
    }

    #[test]
    #[should_panic(expected = "alpha")]
    fn ewma_rejects_zero_alpha() {
        let _ = Ewma::new(0.0);
    }
}
