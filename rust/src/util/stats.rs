//! Small statistics helpers for benchmark reporting.

/// Summary statistics over a sample of f64 measurements.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
    pub stddev: f64,
}

/// Compute summary statistics. Panics on an empty slice.
pub fn summarize(samples: &[f64]) -> Summary {
    assert!(!samples.is_empty(), "no samples");
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).expect("NaN sample"));
    let n = sorted.len();
    let mean = sorted.iter().sum::<f64>() / n as f64;
    let var = sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
    Summary {
        n,
        mean,
        min: sorted[0],
        max: sorted[n - 1],
        p50: percentile(&sorted, 0.50),
        p95: percentile(&sorted, 0.95),
        stddev: var.sqrt(),
    }
}

/// Percentile by linear interpolation over a pre-sorted slice.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Geometric mean (used for cross-benchmark aggregation, like the paper's
/// SPEC FP averages).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let log_sum: f64 = xs.iter().map(|&x| {
        assert!(x > 0.0, "geomean needs positive values");
        x.ln()
    }).sum();
    (log_sum / xs.len() as f64).exp()
}

/// Relative difference |a−b| / max(|a|,|b|) — tolerance checks against the
/// paper's published numbers.
pub fn rel_diff(a: f64, b: f64) -> f64 {
    if a == b {
        return 0.0;
    }
    (a - b).abs() / a.abs().max(b.abs())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
        assert!((s.stddev - 2.0f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let v = [0.0, 10.0];
        assert_eq!(percentile(&v, 0.0), 0.0);
        assert_eq!(percentile(&v, 1.0), 10.0);
        assert_eq!(percentile(&v, 0.25), 2.5);
        assert_eq!(percentile(&[7.0], 0.5), 7.0);
    }

    #[test]
    fn geomean_known() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn rel_diff_symmetric() {
        assert_eq!(rel_diff(1.0, 1.0), 0.0);
        assert!((rel_diff(90.0, 100.0) - 0.1).abs() < 1e-12);
        assert_eq!(rel_diff(100.0, 90.0), rel_diff(90.0, 100.0));
    }
}
