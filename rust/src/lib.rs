// std::simd is nightly-only; the `simd` cargo feature opts into it (see
// the feature's doc block in Cargo.toml — on stable this line is the
// intended E0554 tripwire).
#![cfg_attr(feature = "simd", feature(portable_simd))]

//! # fpmax — a reproduction of the FPMax FPU test chip as a software system
//!
//! FPMax (Pu, Galal, Yang, Shacham, Horowitz; 2016) is a 28nm UTBB FDSOI
//! test chip carrying four floating-point multiply-accumulate (FMAC) units
//! emitted by the FPGen hardware generator: latency-optimized cascade
//! multiply-add (CMA) units and throughput-optimized fused multiply-add
//! (FMA) units, in single and double precision.
//!
//! This crate rebuilds the entire system in simulation:
//!
//! * [`arch`] — the FPU microarchitecture substrate: IEEE-754 codecs, a
//!   golden softfloat FMA, Booth-2/3 partial-product generation, carry-save
//!   compressor trees (Wallace / array / ZM), and the bit-accurate FMA and
//!   CMA datapaths, all generated from an [`arch::FpuConfig`] the way FPGen
//!   generates RTL.
//! * [`arch::engine`] — the unified batched execution layer on top of the
//!   datapaths: the [`arch::engine::Datapath`] trait (scalar + chunked
//!   batch execution, activity accumulation), three **fidelity tiers**
//!   ([`arch::engine::Fidelity::GateLevel`] simulates every 3:2 row and
//!   counts toggles; [`arch::engine::Fidelity::WordLevel`] skips the gate
//!   simulation but stays bit-identical, guarded by sampled cross-checks;
//!   [`arch::engine::Fidelity::WordSimd`] restructures the same spec into
//!   branch-light SoA lane kernels for batch throughput), and the
//!   thread-parallel, allocation-free [`arch::engine::BatchExecutor`]
//!   (persistent worker pool — threads spawn once and park between runs)
//!   that the coordinator, the DSE sweeps, the chip sequencer, and the
//!   benches all issue through. Tracked runs can be **time-resolved**:
//!   [`arch::engine::ActivityTrace`] cuts a run into fixed-width windows
//!   of toggle counts and occupancy (window sums equal the aggregate
//!   accumulator bit-for-bit), which the body-bias controller consumes
//!   to react to workload phases instead of run-level averages.
//! * [`timing`] — FO4-based delay model: per-component logic depth, the
//!   α-power-law FO4(V_DD, V_t), and pipeline stage partitioning.
//! * [`energy`] — 28nm UTBB FDSOI technology model: per-component effective
//!   capacitance and area, dynamic + leakage power, body-bias → V_t shift,
//!   and the feature-size/FO4 scaling rule used for the paper's Table II.
//! * [`pipesim`] — a cycle-accurate pipeline simulator with the internal
//!   (before-rounding) bypass network, used to measure the average latency
//!   penalty of Fig. 2(c) and Fig. 4.
//! * [`workloads`] — SPEC-FP-like dependence-trace generation, throughput
//!   streams, and utilization (duty-cycle) profiles.
//! * [`dse`] — the FPGen design-space-exploration loop: architecture and
//!   voltage sweeps and Pareto-frontier extraction (Fig. 3 / Fig. 4).
//! * [`bb`] — body-bias controllers: static vs dynamically adaptive V_BB
//!   (the 3× → 1.5× low-utilization energy recovery of Fig. 4).
//! * [`chip`] — the FPMax chip testbench of Fig. 5: on-chip RAM banks, a
//!   JTAG-like slow port, the instruction encoding, and the at-speed test
//!   sequencer.
//! * [`runtime`] — run-time services: the **streaming serve layer**
//!   ([`runtime::serve`] — an async submission queue over the persistent
//!   engine: many producers, coalesced fidelity-tiered batches,
//!   per-worker work-stealing dispatch, and a live body-bias controller
//!   fed by a lock-free window ring whose streamed schedule is
//!   bit-identical to the post-hoc pass), the **sharded multi-unit
//!   router** ([`runtime::router`] — one serve shard per unit preset ×
//!   precision × fidelity tier, classified submissions dispatched by the
//!   paper's Table 1 unit affinity with load-aware spill, and
//!   fleet-level accounting that keeps every shard's streamed numbers
//!   bit-identical to its own post-hoc pass), plus the PJRT runtime that
//!   loads the AOT-compiled JAX/Pallas HLO artifacts
//!   (`artifacts/*.hlo.txt`) and executes them from Rust; Python never
//!   runs on the request path.
//! * [`coordinator`] — the asynchronous verification coordinator that
//!   batches operands through both the Rust datapath and the PJRT artifact
//!   and cross-checks them.
//! * [`report`] — emitters that regenerate every table and figure of the
//!   paper's evaluation.
//!
//! ## Quickstart
//!
//! ```no_run
//! use fpmax::arch::{FpuConfig, FpuKind, Precision, FpuUnit};
//!
//! // The paper's SP FMA: 4 stages, Booth-3, ZM reduction tree.
//! let cfg = FpuConfig::sp_fma();
//! let unit = FpuUnit::generate(&cfg);
//! let r = unit.fmac(1.5f32.to_bits() as u64,
//!                   2.0f32.to_bits() as u64,
//!                   0.25f32.to_bits() as u64);
//! assert_eq!(f32::from_bits(r.bits as u32), 1.5 * 2.0 + 0.25);
//! ```
//!
//! Batched execution through the engine (what every high-volume consumer
//! does):
//!
//! ```no_run
//! use fpmax::arch::{BatchExecutor, FpuConfig, FpuUnit};
//! use fpmax::workloads::throughput::{OperandMix, OperandStream};
//!
//! let unit = FpuUnit::generate(&FpuConfig::sp_fma());
//! let triples = OperandStream::new(
//!     fpmax::arch::Precision::Single, OperandMix::Finite, 42).batch(1_000_000);
//! // Word-level tier with a sampled gate-level cross-check: fast AND
//! // provably bit-identical.
//! let (bits, check) = BatchExecutor::auto().run_checked(&unit, &triples, 997);
//! assert!(check.clean());
//! assert_eq!(bits.len(), 1_000_000);
//! ```

pub mod arch;
pub mod bb;
pub mod chip;
pub mod coordinator;
pub mod dse;
pub mod energy;
pub mod pipesim;
pub mod report;
pub mod runtime;
pub mod timing;
pub mod util;
pub mod workloads;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
