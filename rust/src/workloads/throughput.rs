//! Throughput workloads: independent operand streams with values.
//!
//! The throughput units (Fig. 3) are evaluated on GPU-style abundant
//! parallelism — no inter-op dependences, every cycle issues. These
//! generators produce the *operand values* too, because the throughput
//! experiments also feed the chip testbench ([`crate::chip`]) and the
//! AOT-artifact cross-check ([`crate::coordinator`]).

use crate::arch::fp::Precision;
use crate::util::Rng;

/// One FMAC operand triple (raw bits; SP uses the low 32 bits).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OperandTriple {
    pub a: u64,
    pub b: u64,
    pub c: u64,
}

/// A structure-of-arrays operand batch — the layout the PJRT artifact
/// consumes directly and the natural unit of work for the batched
/// execution engine ([`crate::arch::engine`]). Streams emit these so
/// consumers stop re-splitting scalar triples into parallel arrays.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct OperandBatch {
    pub a: Vec<u64>,
    pub b: Vec<u64>,
    pub c: Vec<u64>,
}

impl OperandBatch {
    pub fn with_capacity(n: usize) -> OperandBatch {
        OperandBatch {
            a: Vec::with_capacity(n),
            b: Vec::with_capacity(n),
            c: Vec::with_capacity(n),
        }
    }

    /// Convert from array-of-structs form.
    pub fn from_triples(triples: &[OperandTriple]) -> OperandBatch {
        let mut out = OperandBatch::with_capacity(triples.len());
        for t in triples {
            out.push(*t);
        }
        out
    }

    pub fn push(&mut self, t: OperandTriple) {
        self.a.push(t.a);
        self.b.push(t.b);
        self.c.push(t.c);
    }

    pub fn len(&self) -> usize {
        self.a.len()
    }

    pub fn is_empty(&self) -> bool {
        self.a.is_empty()
    }
}

/// Operand distribution flavours.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OperandMix {
    /// Finite values with exponent spread (the standard test diet).
    Finite,
    /// Everything, including NaN/Inf (robustness runs).
    Anything,
    /// Values near 1.0 (dense-kernel-like activity; exercises the
    /// accumulation cancellation paths rarely).
    Balanced,
}

/// Deterministic operand-stream generator.
#[derive(Debug, Clone)]
pub struct OperandStream {
    precision: Precision,
    mix: OperandMix,
    rng: Rng,
}

impl OperandStream {
    pub fn new(precision: Precision, mix: OperandMix, seed: u64) -> OperandStream {
        OperandStream { precision, mix, rng: Rng::new(seed) }
    }

    /// Next operand triple.
    pub fn next_triple(&mut self) -> OperandTriple {
        OperandTriple { a: self.next_operand(), b: self.next_operand(), c: self.next_operand() }
    }

    /// Generate a batch of `n` triples.
    pub fn batch(&mut self, n: usize) -> Vec<OperandTriple> {
        (0..n).map(|_| self.next_triple()).collect()
    }

    /// Generate a structure-of-arrays batch of `n` triples (same draw
    /// order as [`OperandStream::batch`], so the two forms are
    /// interchangeable at equal seeds).
    pub fn batch_soa(&mut self, n: usize) -> OperandBatch {
        let mut out = OperandBatch::with_capacity(n);
        for _ in 0..n {
            out.push(self.next_triple());
        }
        out
    }

    fn next_operand(&mut self) -> u64 {
        match (self.precision, self.mix) {
            (Precision::Single, OperandMix::Finite) => self.rng.f32_operand() as u64,
            (Precision::Single, OperandMix::Anything) => self.rng.f32_any() as u64,
            (Precision::Single, OperandMix::Balanced) => {
                let v = (self.rng.f64() * 4.0 - 2.0) as f32;
                v.to_bits() as u64
            }
            (Precision::Double, OperandMix::Finite) => self.rng.f64_operand(),
            (Precision::Double, OperandMix::Anything) => self.rng.f64_any(),
            (Precision::Double, OperandMix::Balanced) => {
                (self.rng.f64() * 4.0 - 2.0).to_bits()
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_deterministic() {
        let a = OperandStream::new(Precision::Single, OperandMix::Finite, 1).batch(100);
        let b = OperandStream::new(Precision::Single, OperandMix::Finite, 1).batch(100);
        assert_eq!(a, b);
    }

    #[test]
    fn soa_batch_matches_aos_batch() {
        let aos = OperandStream::new(Precision::Double, OperandMix::Finite, 6).batch(257);
        let soa = OperandStream::new(Precision::Double, OperandMix::Finite, 6).batch_soa(257);
        assert_eq!(soa.len(), 257);
        assert!(!soa.is_empty());
        assert_eq!(OperandBatch::from_triples(&aos), soa);
        assert_eq!((soa.a[100], soa.b[100], soa.c[100]), (aos[100].a, aos[100].b, aos[100].c));
    }

    #[test]
    fn finite_mix_is_finite() {
        let mut s = OperandStream::new(Precision::Single, OperandMix::Finite, 2);
        for _ in 0..5_000 {
            let t = s.next_triple();
            assert!(f32::from_bits(t.a as u32).is_finite());
            assert!(f32::from_bits(t.b as u32).is_finite());
            assert!(f32::from_bits(t.c as u32).is_finite());
        }
        let mut s = OperandStream::new(Precision::Double, OperandMix::Finite, 2);
        for _ in 0..5_000 {
            assert!(f64::from_bits(s.next_triple().a).is_finite());
        }
    }

    #[test]
    fn anything_mix_hits_specials() {
        let mut s = OperandStream::new(Precision::Single, OperandMix::Anything, 3);
        let mut nan = 0;
        for _ in 0..50_000 {
            if f32::from_bits(s.next_triple().a as u32).is_nan() {
                nan += 1;
            }
        }
        assert!(nan > 50, "NaNs undersampled: {nan}");
    }

    #[test]
    fn balanced_mix_in_range() {
        let mut s = OperandStream::new(Precision::Double, OperandMix::Balanced, 4);
        for _ in 0..1_000 {
            let v = f64::from_bits(s.next_triple().b);
            assert!((-2.0..2.0).contains(&v));
        }
    }

    #[test]
    fn sp_operands_fit_32_bits() {
        let mut s = OperandStream::new(Precision::Single, OperandMix::Finite, 5);
        for _ in 0..1_000 {
            let t = s.next_triple();
            assert!(t.a <= u32::MAX as u64 && t.b <= u32::MAX as u64 && t.c <= u32::MAX as u64);
        }
    }
}
