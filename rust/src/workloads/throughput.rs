//! Throughput workloads: independent operand streams with values.
//!
//! The throughput units (Fig. 3) are evaluated on GPU-style abundant
//! parallelism — no inter-op dependences, every cycle issues. These
//! generators produce the *operand values* too, because the throughput
//! experiments also feed the chip testbench ([`crate::chip`]) and the
//! AOT-artifact cross-check ([`crate::coordinator`]).

use crate::arch::fp::Precision;
use crate::util::Rng;

/// One FMAC operand triple (raw bits; SP uses the low 32 bits).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OperandTriple {
    pub a: u64,
    pub b: u64,
    pub c: u64,
}

/// A structure-of-arrays operand batch — the layout the PJRT artifact
/// consumes directly and the natural unit of work for the batched
/// execution engine ([`crate::arch::engine`]). Streams emit these so
/// consumers stop re-splitting scalar triples into parallel arrays.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct OperandBatch {
    pub a: Vec<u64>,
    pub b: Vec<u64>,
    pub c: Vec<u64>,
}

impl OperandBatch {
    pub fn with_capacity(n: usize) -> OperandBatch {
        OperandBatch {
            a: Vec::with_capacity(n),
            b: Vec::with_capacity(n),
            c: Vec::with_capacity(n),
        }
    }

    /// Convert from array-of-structs form.
    pub fn from_triples(triples: &[OperandTriple]) -> OperandBatch {
        let mut out = OperandBatch::with_capacity(triples.len());
        for t in triples {
            out.push(*t);
        }
        out
    }

    pub fn push(&mut self, t: OperandTriple) {
        self.a.push(t.a);
        self.b.push(t.b);
        self.c.push(t.c);
    }

    pub fn len(&self) -> usize {
        self.a.len()
    }

    pub fn is_empty(&self) -> bool {
        self.a.is_empty()
    }
}

/// Operand distribution flavours.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OperandMix {
    /// Finite values with exponent spread (the standard test diet).
    Finite,
    /// Everything, including NaN/Inf (robustness runs).
    Anything,
    /// Values near 1.0 (dense-kernel-like activity; exercises the
    /// accumulation cancellation paths rarely).
    Balanced,
    /// Roughly half the operands are drawn from the special palette
    /// (±zero, subnormal, ±Inf, NaN) — the adversarial diet for the
    /// lane-kernel peel path and the clock-gating accounting, far denser
    /// in specials than uniform-bit sampling.
    SpecialHeavy,
}

/// Deterministic operand-stream generator.
#[derive(Debug, Clone)]
pub struct OperandStream {
    precision: Precision,
    mix: OperandMix,
    rng: Rng,
}

impl OperandStream {
    pub fn new(precision: Precision, mix: OperandMix, seed: u64) -> OperandStream {
        OperandStream { precision, mix, rng: Rng::new(seed) }
    }

    /// Next operand triple.
    pub fn next_triple(&mut self) -> OperandTriple {
        OperandTriple { a: self.next_operand(), b: self.next_operand(), c: self.next_operand() }
    }

    /// Generate a batch of `n` triples.
    pub fn batch(&mut self, n: usize) -> Vec<OperandTriple> {
        (0..n).map(|_| self.next_triple()).collect()
    }

    /// Refill a caller-provided buffer in place — the allocation-free
    /// companion of [`OperandStream::batch`] for steady-state serving
    /// loops (same draw order at equal seeds).
    pub fn fill(&mut self, out: &mut [OperandTriple]) {
        for slot in out.iter_mut() {
            *slot = self.next_triple();
        }
    }

    /// Generate a structure-of-arrays batch of `n` triples (same draw
    /// order as [`OperandStream::batch`], so the two forms are
    /// interchangeable at equal seeds).
    pub fn batch_soa(&mut self, n: usize) -> OperandBatch {
        let mut out = OperandBatch::with_capacity(n);
        for _ in 0..n {
            out.push(self.next_triple());
        }
        out
    }

    fn next_operand(&mut self) -> u64 {
        match (self.precision, self.mix) {
            // SP/DP keep their original draw sequences (seed-stable
            // across PRs); the transprecision tiers take the
            // format-generic equivalents below.
            (Precision::Single, OperandMix::Finite) => self.rng.f32_operand() as u64,
            (Precision::Single, OperandMix::Anything) => self.rng.f32_any() as u64,
            (Precision::Single, OperandMix::Balanced) => {
                let v = (self.rng.f64() * 4.0 - 2.0) as f32;
                v.to_bits() as u64
            }
            (Precision::Double, OperandMix::Finite) => self.rng.f64_operand(),
            (Precision::Double, OperandMix::Anything) => self.rng.f64_any(),
            (Precision::Double, OperandMix::Balanced) => {
                (self.rng.f64() * 4.0 - 2.0).to_bits()
            }
            (_, OperandMix::SpecialHeavy) => self.special_heavy_operand(),
            (_, OperandMix::Finite) => self.finite_operand(),
            (_, OperandMix::Anything) => {
                self.rng.next_u64() & self.precision.format().storage_mask()
            }
            (_, OperandMix::Balanced) => {
                crate::arch::softfloat::from_f64(self.precision.format(), self.rng.f64() * 4.0 - 2.0)
            }
        }
    }

    /// Format-generic finite draw: uniform exponent field (finite
    /// binades only), random fraction — the small-format analogue of
    /// [`Rng::f32_operand`].
    fn finite_operand(&mut self) -> u64 {
        let fmt = self.precision.format();
        let sign = if self.rng.chance(0.5) { fmt.sign_bit() } else { 0 };
        let exp = self.rng.below(fmt.emax_biased());
        let frac = self.rng.next_u64() & fmt.frac_mask();
        sign | (exp << (fmt.sig_bits - 1)) | frac
    }

    /// One SpecialHeavy draw: each special class gets a 1-in-8 slice, the
    /// remaining half of the distribution is the standard finite diet.
    fn special_heavy_operand(&mut self) -> u64 {
        let fmt = self.precision.format();
        let sign = self.rng.chance(0.5);
        match self.rng.below(8) {
            0 => fmt.zero(sign),
            1 => {
                // Nonzero subnormal: biased exponent 0, random fraction.
                let frac = (self.rng.next_u64() & fmt.frac_mask()) | 1;
                fmt.zero(sign) | frac
            }
            2 => fmt.inf(sign),
            3 => {
                // NaN with a random (nonzero) payload, either sign.
                let payload = (self.rng.next_u64() & fmt.frac_mask()) | (fmt.hidden_bit() >> 1);
                fmt.inf(sign) | payload
            }
            _ => match self.precision {
                Precision::Single => self.rng.f32_operand() as u64,
                Precision::Double => self.rng.f64_operand(),
                _ => self.finite_operand(),
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_deterministic() {
        let a = OperandStream::new(Precision::Single, OperandMix::Finite, 1).batch(100);
        let b = OperandStream::new(Precision::Single, OperandMix::Finite, 1).batch(100);
        assert_eq!(a, b);
    }

    #[test]
    fn soa_batch_matches_aos_batch() {
        let aos = OperandStream::new(Precision::Double, OperandMix::Finite, 6).batch(257);
        let soa = OperandStream::new(Precision::Double, OperandMix::Finite, 6).batch_soa(257);
        assert_eq!(soa.len(), 257);
        assert!(!soa.is_empty());
        assert_eq!(OperandBatch::from_triples(&aos), soa);
        assert_eq!((soa.a[100], soa.b[100], soa.c[100]), (aos[100].a, aos[100].b, aos[100].c));
    }

    #[test]
    fn finite_mix_is_finite() {
        let mut s = OperandStream::new(Precision::Single, OperandMix::Finite, 2);
        for _ in 0..5_000 {
            let t = s.next_triple();
            assert!(f32::from_bits(t.a as u32).is_finite());
            assert!(f32::from_bits(t.b as u32).is_finite());
            assert!(f32::from_bits(t.c as u32).is_finite());
        }
        let mut s = OperandStream::new(Precision::Double, OperandMix::Finite, 2);
        for _ in 0..5_000 {
            assert!(f64::from_bits(s.next_triple().a).is_finite());
        }
    }

    #[test]
    fn anything_mix_hits_specials() {
        let mut s = OperandStream::new(Precision::Single, OperandMix::Anything, 3);
        let mut nan = 0;
        for _ in 0..50_000 {
            if f32::from_bits(s.next_triple().a as u32).is_nan() {
                nan += 1;
            }
        }
        assert!(nan > 50, "NaNs undersampled: {nan}");
    }

    #[test]
    fn balanced_mix_in_range() {
        let mut s = OperandStream::new(Precision::Double, OperandMix::Balanced, 4);
        for _ in 0..1_000 {
            let v = f64::from_bits(s.next_triple().b);
            assert!((-2.0..2.0).contains(&v));
        }
    }

    #[test]
    fn special_heavy_mix_covers_all_classes() {
        use crate::arch::fp::{decode, Class};
        for precision in [Precision::Single, Precision::Double] {
            let fmt = precision.format();
            let mut s = OperandStream::new(precision, OperandMix::SpecialHeavy, 11);
            let mut counts = [0usize; 5];
            for _ in 0..4_000 {
                let t = s.next_triple();
                for bits in [t.a, t.b, t.c] {
                    let idx = match decode(fmt, bits).class {
                        Class::Zero => 0,
                        Class::Subnormal => 1,
                        Class::Normal => 2,
                        Class::Infinity => 3,
                        Class::Nan => 4,
                    };
                    counts[idx] += 1;
                }
            }
            for (i, &c) in counts.iter().enumerate() {
                assert!(c > 100, "{precision:?}: class {i} undersampled ({c})");
            }
            // Specials really are heavy: ≳ a third of all draws.
            let specials = counts[0] + counts[1] + counts[3] + counts[4];
            assert!(specials * 3 > 12_000, "specials too rare: {specials}");
        }
    }

    #[test]
    fn small_format_streams_cover_all_mixes() {
        use crate::arch::fp::{decode, Class};
        use crate::arch::softfloat;
        for precision in [
            Precision::Half,
            Precision::Bfloat16,
            Precision::Fp8E4M3,
            Precision::Fp8E5M2,
        ] {
            let fmt = precision.format();
            // Finite: inside storage, never Inf/NaN.
            let mut s = OperandStream::new(precision, OperandMix::Finite, 21);
            for _ in 0..2_000 {
                let t = s.next_triple();
                for bits in [t.a, t.b, t.c] {
                    assert_eq!(bits & !fmt.storage_mask(), 0, "{precision:?} leaked bits");
                    let c = decode(fmt, bits).class;
                    assert!(c != Class::Infinity && c != Class::Nan, "{precision:?} {bits:#x}");
                }
            }
            // Anything: inside storage, specials present (8-bit formats
            // hit the all-ones exponent often).
            let mut s = OperandStream::new(precision, OperandMix::Anything, 22);
            let mut specials = 0;
            for _ in 0..2_000 {
                let t = s.next_triple();
                assert_eq!(t.a & !fmt.storage_mask(), 0);
                if decode(fmt, t.a).non_finite() {
                    specials += 1;
                }
            }
            assert!(specials > 0, "{precision:?}: Anything never drew a special");
            // Balanced: values in [-2, 2] after rounding into fmt.
            let mut s = OperandStream::new(precision, OperandMix::Balanced, 23);
            for _ in 0..500 {
                let v = softfloat::to_f64(fmt, s.next_triple().b);
                assert!((-2.0..=2.0).contains(&v), "{precision:?}: {v}");
            }
            // SpecialHeavy: all five classes appear.
            let mut s = OperandStream::new(precision, OperandMix::SpecialHeavy, 24);
            let mut counts = [0usize; 5];
            for _ in 0..3_000 {
                let t = s.next_triple();
                for bits in [t.a, t.b, t.c] {
                    counts[match decode(fmt, bits).class {
                        Class::Zero => 0,
                        Class::Subnormal => 1,
                        Class::Normal => 2,
                        Class::Infinity => 3,
                        Class::Nan => 4,
                    }] += 1;
                }
            }
            for (i, &c) in counts.iter().enumerate() {
                assert!(c > 50, "{precision:?}: class {i} undersampled ({c})");
            }
        }
    }

    #[test]
    fn fill_matches_batch_at_equal_seed() {
        let want = OperandStream::new(Precision::Single, OperandMix::SpecialHeavy, 8).batch(333);
        let mut buf = vec![OperandTriple { a: 0, b: 0, c: 0 }; 333];
        OperandStream::new(Precision::Single, OperandMix::SpecialHeavy, 8).fill(&mut buf);
        assert_eq!(want, buf);
    }

    #[test]
    fn sp_operands_fit_32_bits() {
        let mut s = OperandStream::new(Precision::Single, OperandMix::Finite, 5);
        for _ in 0..1_000 {
            let t = s.next_triple();
            assert!(t.a <= u32::MAX as u64 && t.b <= u32::MAX as u64 && t.c <= u32::MAX as u64);
        }
    }
}
