//! Throughput workloads: independent operand streams with values.
//!
//! The throughput units (Fig. 3) are evaluated on GPU-style abundant
//! parallelism — no inter-op dependences, every cycle issues. These
//! generators produce the *operand values* too, because the throughput
//! experiments also feed the chip testbench ([`crate::chip`]) and the
//! AOT-artifact cross-check ([`crate::coordinator`]).

use crate::arch::fp::Precision;
use crate::util::Rng;

/// One FMAC operand triple (raw bits; SP uses the low 32 bits).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OperandTriple {
    pub a: u64,
    pub b: u64,
    pub c: u64,
}

/// Operand distribution flavours.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OperandMix {
    /// Finite values with exponent spread (the standard test diet).
    Finite,
    /// Everything, including NaN/Inf (robustness runs).
    Anything,
    /// Values near 1.0 (dense-kernel-like activity; exercises the
    /// accumulation cancellation paths rarely).
    Balanced,
}

/// Deterministic operand-stream generator.
#[derive(Debug, Clone)]
pub struct OperandStream {
    precision: Precision,
    mix: OperandMix,
    rng: Rng,
}

impl OperandStream {
    pub fn new(precision: Precision, mix: OperandMix, seed: u64) -> OperandStream {
        OperandStream { precision, mix, rng: Rng::new(seed) }
    }

    /// Next operand triple.
    pub fn next_triple(&mut self) -> OperandTriple {
        OperandTriple { a: self.next_operand(), b: self.next_operand(), c: self.next_operand() }
    }

    /// Generate a batch of `n` triples.
    pub fn batch(&mut self, n: usize) -> Vec<OperandTriple> {
        (0..n).map(|_| self.next_triple()).collect()
    }

    fn next_operand(&mut self) -> u64 {
        match (self.precision, self.mix) {
            (Precision::Single, OperandMix::Finite) => self.rng.f32_operand() as u64,
            (Precision::Single, OperandMix::Anything) => self.rng.f32_any() as u64,
            (Precision::Single, OperandMix::Balanced) => {
                let v = (self.rng.f64() * 4.0 - 2.0) as f32;
                v.to_bits() as u64
            }
            (Precision::Double, OperandMix::Finite) => self.rng.f64_operand(),
            (Precision::Double, OperandMix::Anything) => self.rng.f64_any(),
            (Precision::Double, OperandMix::Balanced) => {
                (self.rng.f64() * 4.0 - 2.0).to_bits()
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_deterministic() {
        let a = OperandStream::new(Precision::Single, OperandMix::Finite, 1).batch(100);
        let b = OperandStream::new(Precision::Single, OperandMix::Finite, 1).batch(100);
        assert_eq!(a, b);
    }

    #[test]
    fn finite_mix_is_finite() {
        let mut s = OperandStream::new(Precision::Single, OperandMix::Finite, 2);
        for _ in 0..5_000 {
            let t = s.next_triple();
            assert!(f32::from_bits(t.a as u32).is_finite());
            assert!(f32::from_bits(t.b as u32).is_finite());
            assert!(f32::from_bits(t.c as u32).is_finite());
        }
        let mut s = OperandStream::new(Precision::Double, OperandMix::Finite, 2);
        for _ in 0..5_000 {
            assert!(f64::from_bits(s.next_triple().a).is_finite());
        }
    }

    #[test]
    fn anything_mix_hits_specials() {
        let mut s = OperandStream::new(Precision::Single, OperandMix::Anything, 3);
        let mut nan = 0;
        for _ in 0..50_000 {
            if f32::from_bits(s.next_triple().a as u32).is_nan() {
                nan += 1;
            }
        }
        assert!(nan > 50, "NaNs undersampled: {nan}");
    }

    #[test]
    fn balanced_mix_in_range() {
        let mut s = OperandStream::new(Precision::Double, OperandMix::Balanced, 4);
        for _ in 0..1_000 {
            let v = f64::from_bits(s.next_triple().b);
            assert!((-2.0..2.0).contains(&v));
        }
    }

    #[test]
    fn sp_operands_fit_32_bits() {
        let mut s = OperandStream::new(Precision::Single, OperandMix::Finite, 5);
        for _ in 0..1_000 {
            let t = s.next_triple();
            assert!(t.a <= u32::MAX as u64 && t.b <= u32::MAX as u64 && t.c <= u32::MAX as u64);
        }
    }
}
