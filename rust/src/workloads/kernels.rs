//! Kernel-program builder for the chip sequencer: GEMM tiles, 3-tap
//! stencils, and dot-product reduction chains compiled to repeat-buffer
//! programs ([`crate::chip::SeqWord`]) for any fabricated unit preset.
//!
//! Every kernel is a list of [`Pass`]es. A pass arms up to three stream
//! semantic registers and issues one micro-op `count` times; passes
//! chain through the result bank (a later pass's stream reads an
//! earlier pass's output region). From the same pass list the builder
//! emits two programs over identical stimulus data:
//!
//! * [`KernelProgram::repeat_words`] — each pass is `count` iterations
//!   of a one-word repeat window, the Snitch-FREP-shaped encoding that
//!   issues one FPU op per cycle with a single pipeline drain per pass;
//! * [`KernelProgram::unrolled_words`] — the same micro-op written
//!   `count` times, paying the classic per-instruction drain.
//!
//! Both consume their streams element-for-element in the same order, so
//! the result banks must match bit-for-bit — kernel correctness is a
//! straight `read_bank` diff, not a tolerance comparison. The micro-op
//! is never `Nop`: an all-zero-field `Nop` encodes to the all-zero halt
//! word, which would end the program instead of issuing a bubble.

use crate::arch::fp::Precision;
use crate::arch::rounding::RoundMode;
use crate::chip::isa::{
    Instruction, Op, SeqWord, SrcSel, StreamBank, StreamDesc, StreamPort, UnitSel,
    STREAM_STRIDE_MAX,
};
use crate::chip::{FpMaxChip, BANK_PROGRAM, BANK_STIM_A, BANK_STIM_B, BANK_STIM_C};
use crate::util::Rng;

/// One kernel pass: up to three armed stream registers and a micro-op
/// issued `count` times. A `None` stream slot emits an explicit disarm
/// word, so every pass fully determines all three stream registers.
#[derive(Debug, Clone)]
pub struct Pass {
    pub streams: [Option<StreamDesc>; 3],
    pub micro: Instruction,
    pub count: u32,
}

/// A compiled kernel: stimulus data plus the pass list, emitted as
/// either the repeat-buffer program or the unrolled reference.
#[derive(Debug, Clone)]
pub struct KernelProgram {
    pub name: String,
    pub unit: UnitSel,
    pub stim_a: Vec<u64>,
    pub stim_b: Vec<u64>,
    pub stim_c: Vec<u64>,
    pub passes: Vec<Pass>,
    /// First word of the kernel's final output in the result bank.
    pub out_base: usize,
    /// Words of final output (earlier words are intermediate passes).
    pub out_len: usize,
}

impl Pass {
    fn push_arm_words(&self, out: &mut Vec<u64>) {
        for (slot, port) in StreamPort::ALL.iter().enumerate() {
            let desc = self.streams[slot].unwrap_or_else(|| StreamDesc::disarm(*port));
            debug_assert_eq!(desc.port, *port, "stream slot {slot} armed for the wrong port");
            out.push(SeqWord::Stream(desc).encode());
        }
    }
}

impl KernelProgram {
    /// Total FPU ops the kernel issues (== results written).
    pub fn ops(&self) -> u64 {
        self.passes.iter().map(|p| p.count as u64).sum()
    }

    /// Result-bank words written across all passes.
    pub fn results_total(&self) -> usize {
        self.ops() as usize
    }

    /// Stimulus/result RAM depth both program variants need.
    pub fn ram_depth(&self) -> usize {
        self.stim_a
            .len()
            .max(self.stim_b.len())
            .max(self.stim_c.len())
            .max(self.results_total())
    }

    /// The repeat-buffer encoding: per pass, three stream words, a
    /// `Repeat { window: 1, count }`, and the single micro-op word.
    pub fn repeat_words(&self) -> Vec<u64> {
        let mut out = Vec::new();
        for pass in &self.passes {
            pass.push_arm_words(&mut out);
            out.push(SeqWord::Repeat { window: 1, count: pass.count }.encode());
            let w = pass.micro.encode() as u64;
            assert_ne!(w, 0, "micro-op encodes to the halt word");
            out.push(w);
        }
        out
    }

    /// The unrolled reference encoding: the same stream words, then the
    /// micro-op written `count` times (one full issue+drain each).
    pub fn unrolled_words(&self) -> Vec<u64> {
        let mut out = Vec::new();
        for pass in &self.passes {
            pass.push_arm_words(&mut out);
            let w = pass.micro.encode() as u64;
            assert_ne!(w, 0, "micro-op encodes to the halt word");
            out.extend(std::iter::repeat(w).take(pass.count as usize));
        }
        out
    }

    /// A chip sized for this kernel, stimulus banks loaded. The program
    /// RAM fits whichever word list the caller passes next.
    pub fn fresh_chip(&self, program_words: usize) -> crate::Result<FpMaxChip> {
        let mut chip = FpMaxChip::with_depths(self.ram_depth(), program_words + 1);
        let mut port = chip.jtag();
        port.load_bank(BANK_STIM_A, &self.stim_a)?;
        port.load_bank(BANK_STIM_B, &self.stim_b)?;
        port.load_bank(BANK_STIM_C, &self.stim_c)?;
        Ok(chip)
    }

    /// Load `words` into a fresh, stimulus-loaded chip.
    pub fn loaded_chip(&self, words: &[u64]) -> crate::Result<FpMaxChip> {
        let mut chip = self.fresh_chip(words.len())?;
        chip.jtag().load_bank(BANK_PROGRAM, words)?;
        Ok(chip)
    }
}

/// Seeded operand values in `[-1, 1)` encoded in the unit's precision —
/// small magnitudes so chained kernels stay comfortably finite.
fn operand_bits(rng: &mut Rng, precision: Precision, n: usize) -> Vec<u64> {
    (0..n)
        .map(|_| {
            let v = rng.f64() * 2.0 - 1.0;
            match precision {
                Precision::Single => (v as f32).to_bits() as u64,
                Precision::Double => v.to_bits(),
                p => crate::arch::softfloat::from_f64(p.format(), v),
            }
        })
        .collect()
}

fn fmac_micro(unit: UnitSel, src_c: SrcSel) -> Instruction {
    Instruction {
        unit,
        op: Op::Fmac,
        rounding: RoundMode::NearestEven,
        src_a: SrcSel::Ram,
        src_b: SrcSel::Ram,
        src_c,
        base_addr: 0,
        repeat: 0,
    }
}

fn stim(port: StreamPort, base: usize, stride0: i16, len0: usize, stride1: i16) -> StreamDesc {
    StreamDesc {
        port,
        bank: StreamBank::Stim,
        base: base as u16,
        stride0,
        len0: len0 as u16,
        stride1,
    }
}

fn result(port: StreamPort, base: usize, stride0: i16, len0: usize, stride1: i16) -> StreamDesc {
    StreamDesc {
        port,
        bank: StreamBank::Result,
        base: base as u16,
        stride0,
        len0: len0 as u16,
        stride1,
    }
}

/// `C[i][j] = Σ_k A[i][k]·B[k][j] + C0[i][j]` as K chained passes of
/// M·N FMACs each. Pass `k` streams column `k` of row-major `A`
/// (broadcast across each output row via a zero inner stride), row `k`
/// of row-major `B`, and the previous pass's full tile from the result
/// bank (`C0` from stimulus on pass 0). The accumulation order is the
/// natural k-loop, so a host reference must chain `mul_add`s in `k`
/// order to match the FMA presets bit-for-bit.
pub fn gemm_tile(unit: UnitSel, m: usize, n: usize, k: usize, seed: u64) -> KernelProgram {
    assert!(m >= 1 && n >= 1 && k >= 1, "degenerate GEMM tile");
    let tile = m * n;
    assert!(tile <= u16::MAX as usize, "tile exceeds a stream length field");
    assert!(k <= STREAM_STRIDE_MAX as usize, "K exceeds a stream stride field");
    assert!(k * tile <= u16::MAX as usize, "accumulator chain exceeds a stream base field");
    let prec = unit.precision();
    let mut rng = Rng::new(seed ^ 0x6e34_4c5a_91ec_0001);
    let stim_a = operand_bits(&mut rng, prec, m * k);
    let stim_b = operand_bits(&mut rng, prec, k * n);
    let stim_c = operand_bits(&mut rng, prec, tile);
    let passes = (0..k)
        .map(|kk| {
            let c_desc = if kk == 0 {
                stim(StreamPort::C, 0, 1, tile, 0)
            } else {
                result(StreamPort::C, (kk - 1) * tile, 1, tile, 0)
            };
            Pass {
                streams: [
                    Some(stim(StreamPort::A, kk, 0, n, k as i16)),
                    Some(stim(StreamPort::B, kk * n, 1, n, 0)),
                    Some(c_desc),
                ],
                micro: fmac_micro(unit, SrcSel::Ram),
                count: tile as u32,
            }
        })
        .collect();
    KernelProgram {
        name: format!("gemm{m}x{n}x{k}"),
        unit,
        stim_a,
        stim_b,
        stim_c,
        passes,
        out_base: (k - 1) * tile,
        out_len: tile,
    }
}

/// 3-tap stencil `y[j] = w0·x[j] + w1·x[j+1] + w2·x[j+2]` over `width`
/// outputs: three passes of `width` FMACs, each broadcasting one weight
/// on port B and chaining the running sum through the result bank.
pub fn stencil3(unit: UnitSel, width: usize, seed: u64) -> KernelProgram {
    assert!(width >= 1, "degenerate stencil");
    assert!(3 * width <= u16::MAX as usize, "stencil exceeds a stream base field");
    let prec = unit.precision();
    let mut rng = Rng::new(seed ^ 0x6e34_4c5a_91ec_0002);
    let stim_a = operand_bits(&mut rng, prec, width + 2);
    let stim_b = operand_bits(&mut rng, prec, 3);
    let passes = (0..3usize)
        .map(|tap| {
            let (c_sel, c_desc) = if tap == 0 {
                (SrcSel::Zero, None)
            } else {
                (SrcSel::Ram, Some(result(StreamPort::C, (tap - 1) * width, 1, width, 0)))
            };
            Pass {
                streams: [
                    Some(stim(StreamPort::A, tap, 1, width, 0)),
                    Some(stim(StreamPort::B, tap, 0, 1, 0)),
                    c_desc,
                ],
                micro: fmac_micro(unit, c_sel),
                count: width as u32,
            }
        })
        .collect();
    KernelProgram {
        name: format!("stencil3x{width}"),
        unit,
        stim_a,
        stim_b,
        stim_c: Vec::new(),
        passes,
        out_base: 2 * width,
        out_len: width,
    }
}

/// `chains` independent dot products of length `len` (a power of two):
/// one elementwise-product pass, then a pairwise reduction tree —
/// `log2(len)` passes of `a·1 + c` adds whose two input streams walk
/// the previous level's partial sums at stride 2. Chain `c`'s product
/// lane occupies `[c·len, (c+1)·len)` in both stimulus banks.
pub fn dot_chains(unit: UnitSel, chains: usize, len: usize, seed: u64) -> KernelProgram {
    assert!(chains >= 1 && len >= 2, "degenerate dot chains");
    assert!(len.is_power_of_two(), "chain length must be a power of two");
    assert!(chains * len <= u16::MAX as usize, "chains exceed a stream length field");
    assert!(len <= STREAM_STRIDE_MAX as usize, "chain length exceeds a stream stride field");
    let prec = unit.precision();
    let mut rng = Rng::new(seed ^ 0x6e34_4c5a_91ec_0003);
    let total = chains * len;
    let stim_a = operand_bits(&mut rng, prec, total);
    let stim_b = operand_bits(&mut rng, prec, total);
    let mut passes = vec![Pass {
        streams: [
            Some(stim(StreamPort::A, 0, 1, total, 0)),
            Some(stim(StreamPort::B, 0, 1, total, 0)),
            None,
        ],
        micro: fmac_micro(unit, SrcSel::Zero),
        count: total as u32,
    }];
    let mut written = total; // result words emitted so far
    let mut prev_base = 0usize; // where the previous level's sums start
    let mut span = len; // previous level's per-chain width
    while span > 1 {
        let out_span = span / 2;
        passes.push(Pass {
            streams: [
                Some(result(StreamPort::A, prev_base, 2, out_span, span as i16)),
                None,
                Some(result(StreamPort::C, prev_base + 1, 2, out_span, span as i16)),
            ],
            micro: Instruction { src_b: SrcSel::One, ..fmac_micro(unit, SrcSel::Ram) },
            count: (chains * out_span) as u32,
        });
        prev_base = written;
        written += chains * out_span;
        span = out_span;
    }
    assert!(written <= u16::MAX as usize, "reduction tree exceeds a stream base field");
    KernelProgram {
        name: format!("dot{chains}x{len}"),
        unit,
        stim_a,
        stim_b,
        stim_c: Vec::new(),
        passes,
        out_base: written - chains,
        out_len: chains,
    }
}

/// The default kernel suite for one unit preset, paper-scaled shapes:
/// a 16×16×8 GEMM tile, a 256-wide 3-tap stencil, and 16 chains of
/// 64-element dot products.
pub fn default_suite(unit: UnitSel, seed: u64) -> Vec<KernelProgram> {
    vec![
        gemm_tile(unit, 16, 16, 8, seed),
        stencil3(unit, 256, seed),
        dot_chains(unit, 16, 64, seed),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chip::BANK_RESULT;

    /// Run both encodings of a kernel and return (repeat stats, repeat
    /// results, unrolled stats, unrolled results) over the full result
    /// bank.
    fn run_both(
        prog: &KernelProgram,
    ) -> (
        crate::chip::RunStats,
        Vec<u64>,
        crate::chip::RunStats,
        Vec<u64>,
    ) {
        let total = prog.results_total();
        let rep = prog.repeat_words();
        let mut chip = prog.loaded_chip(&rep).unwrap();
        let stats_r = chip.run().unwrap();
        let out_r = chip.jtag().read_bank(BANK_RESULT, total).unwrap();
        let unr = prog.unrolled_words();
        let mut chip = prog.loaded_chip(&unr).unwrap();
        let stats_u = chip.run().unwrap();
        let out_u = chip.jtag().read_bank(BANK_RESULT, total).unwrap();
        (stats_r, out_r, stats_u, out_u)
    }

    #[test]
    fn kernels_bit_identical_repeat_vs_unrolled_on_all_presets() {
        for unit in UnitSel::ALL {
            for prog in [
                gemm_tile(unit, 4, 4, 3, 7),
                stencil3(unit, 16, 7),
                dot_chains(unit, 4, 8, 7),
            ] {
                let (stats_r, out_r, stats_u, out_u) = run_both(&prog);
                assert_eq!(out_r, out_u, "{} on {}", prog.name, unit.name());
                assert_eq!(stats_r.ops, prog.ops(), "{}", prog.name);
                assert_eq!(stats_u.ops, prog.ops(), "{}", prog.name);
                assert_eq!(stats_r.results_written, prog.ops(), "{}", prog.name);
                assert!(
                    stats_r.cycles < stats_u.cycles,
                    "{} on {}: repeat {} cycles vs unrolled {}",
                    prog.name,
                    unit.name(),
                    stats_r.cycles,
                    stats_u.cycles
                );
                assert_eq!(stats_u.repeat_cycles, 0, "unrolled path must not use the buffer");
            }
        }
    }

    #[test]
    fn default_suite_hits_the_kernel_gates() {
        for unit in [UnitSel::SpFma, UnitSel::DpCma] {
            for prog in default_suite(unit, 42) {
                let (stats_r, out_r, stats_u, out_u) = run_both(&prog);
                assert_eq!(out_r, out_u, "{}", prog.name);
                let occ = stats_r.repeat_occupancy();
                assert!(occ >= 0.9, "{} occupancy {occ}", prog.name);
                let speedup = stats_u.cycles as f64 / stats_r.cycles as f64;
                assert!(speedup >= 1.5, "{} speedup {speedup}", prog.name);
            }
        }
    }

    #[test]
    fn gemm_tile_matches_host_matmul_on_fma_presets() {
        // FMA presets fuse each multiply-add with one rounding, so the
        // host's `mul_add` chained in the kernel's k-order reproduces
        // the tile exactly. (CMA presets round twice per op — they are
        // covered by the repeat-vs-unrolled identity above.)
        let (m, n, k) = (5, 6, 4);
        let prog = gemm_tile(UnitSel::SpFma, m, n, k, 11);
        let rep = prog.repeat_words();
        let mut chip = prog.loaded_chip(&rep).unwrap();
        chip.run().unwrap();
        let out = chip
            .jtag()
            .read_bank(BANK_RESULT, prog.out_base + prog.out_len)
            .unwrap();
        for i in 0..m {
            for j in 0..n {
                let mut acc = f32::from_bits(prog.stim_c[i * n + j] as u32);
                for kk in 0..k {
                    let a = f32::from_bits(prog.stim_a[i * k + kk] as u32);
                    let b = f32::from_bits(prog.stim_b[kk * n + j] as u32);
                    acc = a.mul_add(b, acc);
                }
                let got = f32::from_bits(out[prog.out_base + i * n + j] as u32);
                assert_eq!(got.to_bits(), acc.to_bits(), "C[{i}][{j}]");
            }
        }
    }

    #[test]
    fn dot_chains_match_host_pairwise_reduction() {
        let (chains, len) = (3, 8);
        let prog = dot_chains(UnitSel::DpFma, chains, len, 23);
        let rep = prog.repeat_words();
        let mut chip = prog.loaded_chip(&rep).unwrap();
        chip.run().unwrap();
        let out = chip
            .jtag()
            .read_bank(BANK_RESULT, prog.out_base + prog.out_len)
            .unwrap();
        for c in 0..chains {
            let mut level: Vec<f64> = (0..len)
                .map(|i| {
                    let x = f64::from_bits(prog.stim_a[c * len + i]);
                    let y = f64::from_bits(prog.stim_b[c * len + i]);
                    x.mul_add(y, 0.0)
                })
                .collect();
            while level.len() > 1 {
                level = level.chunks(2).map(|p| p[0].mul_add(1.0, p[1])).collect();
            }
            let got = f64::from_bits(out[prog.out_base + c]);
            assert_eq!(got.to_bits(), level[0].to_bits(), "chain {c}");
        }
    }

    #[test]
    fn stencil_matches_host_taps() {
        let width = 12;
        let prog = stencil3(UnitSel::SpFma, width, 31);
        let rep = prog.repeat_words();
        let mut chip = prog.loaded_chip(&rep).unwrap();
        chip.run().unwrap();
        let out = chip
            .jtag()
            .read_bank(BANK_RESULT, prog.out_base + prog.out_len)
            .unwrap();
        let x: Vec<f32> = prog.stim_a.iter().map(|&w| f32::from_bits(w as u32)).collect();
        let w: Vec<f32> = prog.stim_b.iter().map(|&w| f32::from_bits(w as u32)).collect();
        for j in 0..width {
            let mut acc = w[0].mul_add(x[j], 0.0);
            acc = w[1].mul_add(x[j + 1], acc);
            acc = w[2].mul_add(x[j + 2], acc);
            let got = f32::from_bits(out[prog.out_base + j] as u32);
            assert_eq!(got.to_bits(), acc.to_bits(), "y[{j}]");
        }
    }
}
