//! Utilization (duty-cycle) profiles — the workload dimension of Fig. 4.
//!
//! "Many applications use FP, but do not use it extensively" (§Chip
//! Implementation): the FPU sees bursts of work separated by long idle
//! gaps. A [`UtilizationProfile`] is a deterministic active/idle
//! schedule; the body-bias controller ([`crate::bb`]) consumes it to
//! decide when the adaptive policy pays off.

use crate::util::Rng;

/// One segment of the schedule.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Segment {
    pub active: bool,
    pub cycles: u64,
}

/// A deterministic active/idle schedule.
#[derive(Debug, Clone, PartialEq)]
pub struct UtilizationProfile {
    pub name: String,
    pub segments: Vec<Segment>,
}

impl UtilizationProfile {
    /// Fully active (the 100%-utilization curves of Fig. 4).
    pub fn full(cycles: u64) -> UtilizationProfile {
        UtilizationProfile {
            name: "100%".into(),
            segments: vec![Segment { active: true, cycles }],
        }
    }

    /// Periodic duty cycle: bursts of `burst` active cycles at the given
    /// utilization (the 10%-utilization curves of Fig. 4 use
    /// `duty(0.1, …)`).
    pub fn duty(utilization: f64, burst: u64, total: u64) -> UtilizationProfile {
        assert!(utilization > 0.0 && utilization <= 1.0);
        let period = (burst as f64 / utilization).round() as u64;
        let idle = period - burst;
        let mut segments = Vec::new();
        let mut done = 0;
        while done < total {
            let b = burst.min(total - done);
            segments.push(Segment { active: true, cycles: b });
            done += b;
            if done >= total {
                break;
            }
            let i = idle.min(total - done);
            if i > 0 {
                segments.push(Segment { active: false, cycles: i });
                done += i;
            }
        }
        UtilizationProfile { name: format!("{:.0}% duty", utilization * 100.0), segments }
    }

    /// Randomized bursty schedule with geometric burst/idle lengths
    /// around a target utilization.
    pub fn bursty(utilization: f64, mean_burst: u64, total: u64, seed: u64) -> UtilizationProfile {
        assert!(utilization > 0.0 && utilization < 1.0);
        let mean_idle = (mean_burst as f64 * (1.0 - utilization) / utilization).max(1.0);
        let mut rng = Rng::new(seed);
        let mut segments = Vec::new();
        let mut done = 0u64;
        let mut active = true;
        while done < total {
            let mean = if active { mean_burst as f64 } else { mean_idle };
            // Geometric with the given mean (≥1).
            let mut len = 1u64;
            while rng.chance(1.0 - 1.0 / mean) && len < 100_000 {
                len += 1;
            }
            let len = len.min(total - done);
            segments.push(Segment { active, cycles: len });
            done += len;
            active = !active;
        }
        UtilizationProfile { name: format!("bursty {:.0}%", utilization * 100.0), segments }
    }

    /// Total cycles covered.
    pub fn total_cycles(&self) -> u64 {
        self.segments.iter().map(|s| s.cycles).sum()
    }

    /// Active cycles.
    pub fn active_cycles(&self) -> u64 {
        self.segments.iter().filter(|s| s.active).map(|s| s.cycles).sum()
    }

    /// Achieved utilization.
    pub fn utilization(&self) -> f64 {
        let t = self.total_cycles();
        if t == 0 {
            0.0
        } else {
            self.active_cycles() as f64 / t as f64
        }
    }

    /// Number of idle→active transitions (the adaptive BB controller
    /// pays a wake-up cost per transition).
    pub fn wakeups(&self) -> u64 {
        let mut n = 0;
        let mut prev_active = true;
        for s in &self.segments {
            if s.active && !prev_active {
                n += 1;
            }
            prev_active = s.active;
        }
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_profile() {
        let p = UtilizationProfile::full(1000);
        assert_eq!(p.total_cycles(), 1000);
        assert_eq!(p.utilization(), 1.0);
        assert_eq!(p.wakeups(), 0);
    }

    #[test]
    fn duty_cycle_hits_target() {
        for u in [0.1, 0.25, 0.5] {
            let p = UtilizationProfile::duty(u, 100, 1_000_000);
            assert!((p.utilization() - u).abs() < 0.01, "target {u}: {}", p.utilization());
            assert_eq!(p.total_cycles(), 1_000_000);
            assert!(p.wakeups() > 0);
        }
    }

    #[test]
    fn bursty_hits_target_approximately() {
        let p = UtilizationProfile::bursty(0.1, 200, 2_000_000, 11);
        assert!((p.utilization() - 0.1).abs() < 0.03, "{}", p.utilization());
        assert_eq!(p.total_cycles(), 2_000_000);
        // Deterministic.
        let q = UtilizationProfile::bursty(0.1, 200, 2_000_000, 11);
        assert_eq!(p, q);
    }

    #[test]
    fn wakeup_counting() {
        let p = UtilizationProfile {
            name: "t".into(),
            segments: vec![
                Segment { active: true, cycles: 10 },
                Segment { active: false, cycles: 10 },
                Segment { active: true, cycles: 10 },
                Segment { active: false, cycles: 5 },
                Segment { active: true, cycles: 1 },
            ],
        };
        assert_eq!(p.wakeups(), 2);
        assert_eq!(p.active_cycles(), 21);
    }
}
