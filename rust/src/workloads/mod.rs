//! Workload generation: SPEC-FP-like dependence traces ([`specfp`]),
//! independent throughput streams with operand values ([`throughput`]),
//! duty-cycle schedules ([`utilization`]), and chip-sequencer kernel
//! programs ([`kernels`]).

pub mod kernels;
pub mod specfp;
pub mod throughput;
pub mod utilization;

pub use kernels::{default_suite, dot_chains, gemm_tile, stencil3, KernelProgram, Pass};
pub use specfp::Profile;
pub use throughput::{OperandBatch, OperandMix, OperandStream, OperandTriple};
pub use utilization::{Segment, UtilizationProfile};
