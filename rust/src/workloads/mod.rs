//! Workload generation: SPEC-FP-like dependence traces ([`specfp`]),
//! independent throughput streams with operand values ([`throughput`]),
//! and duty-cycle schedules ([`utilization`]).

pub mod specfp;
pub mod throughput;
pub mod utilization;

pub use specfp::Profile;
pub use throughput::{OperandBatch, OperandMix, OperandStream, OperandTriple};
pub use utilization::{Segment, UtilizationProfile};
