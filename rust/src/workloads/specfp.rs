//! Synthetic SPEC-FP-like dependence traces.
//!
//! The paper measures its latency units' average latency penalty "in
//! SPEC FP benchmarks" (Fig. 2(c), Fig. 4). SPEC traces are not
//! redistributable, so we generate dependence streams whose *structure*
//! matches the published characterizations of SPEC CFP2006 FP slices:
//!
//! * accumulation dependences (result → next op's addend) dominate —
//!   dot products, stencils, reductions;
//! * multiplier-input dependences (result → next op's multiplicand) are
//!   a substantial minority — Horner kernels, normalization;
//! * dependence distances cluster tightly at 1–2 with a geometric tail
//!   (compiler scheduling covers the rest).
//!
//! Each named profile fixes `(p_acc, p_mul, distance tail)`; the suite
//! spans mixes on both sides of the aggregate so the Fig. 2(c)
//! comparison is robust to the exact mix. This substitution is recorded
//! in DESIGN.md §Hardware gates → substitutions.

use crate::pipesim::trace::{Trace, TraceOp};
use crate::util::Rng;

/// A named benchmark profile.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Profile {
    pub name: &'static str,
    /// Fraction of ops whose producer feeds their accumulator input.
    pub p_acc: f64,
    /// Fraction of ops whose producer feeds a multiplier input.
    pub p_mul: f64,
    /// Geometric-tail parameter for dependence distance (P(d = k+1 | d >
    /// k) for k ≥ 1); 0 ⇒ all distances are 1.
    pub distance_tail: f64,
}

impl Profile {
    /// The synthetic SPEC-FP-like suite. Mix fractions bracket the
    /// aggregate behaviour the paper's Fig. 2(c) averages over:
    /// accumulation-heavy numeric kernels through balanced and
    /// independence-rich codes.
    pub fn suite() -> Vec<Profile> {
        vec![
            // Dense linear algebra: long dot-product reductions.
            Profile { name: "synth.blas3", p_acc: 0.55, p_mul: 0.15, distance_tail: 0.20 },
            // Stencil sweeps: accumulation chains with some distance-2.
            Profile { name: "synth.stencil", p_acc: 0.45, p_mul: 0.20, distance_tail: 0.35 },
            // Spectral/FFT-like: balanced mix, more multiplier reuse.
            Profile { name: "synth.spectral", p_acc: 0.30, p_mul: 0.30, distance_tail: 0.30 },
            // Particle/n-body: heavy accumulate, short distances.
            Profile { name: "synth.nbody", p_acc: 0.60, p_mul: 0.10, distance_tail: 0.15 },
            // Sparse/irregular: fewer chains, longer distances.
            Profile { name: "synth.sparse", p_acc: 0.25, p_mul: 0.15, distance_tail: 0.50 },
            // Horner-style polynomial kernels: multiplier-dependence heavy.
            Profile { name: "synth.horner", p_acc: 0.15, p_mul: 0.45, distance_tail: 0.20 },
            // ODE integrators: accumulate-dominated, medium tail.
            Profile { name: "synth.ode", p_acc: 0.50, p_mul: 0.18, distance_tail: 0.25 },
            // Mostly independent (vectorized) code.
            Profile { name: "synth.vector", p_acc: 0.12, p_mul: 0.08, distance_tail: 0.30 },
        ]
    }

    /// Generate a trace of `n` ops with a deterministic seed.
    pub fn generate(&self, n: usize, seed: u64) -> Trace {
        assert!(self.p_acc + self.p_mul <= 1.0, "dependence fractions exceed 1");
        let mut rng = Rng::new(seed ^ fxhash(self.name));
        let mut ops = Vec::with_capacity(n);
        for i in 0..n {
            if i == 0 {
                ops.push(TraceOp::INDEPENDENT);
                continue;
            }
            let u = rng.f64();
            let op = if u < self.p_acc {
                TraceOp::accumulate(self.distance(&mut rng, i))
            } else if u < self.p_acc + self.p_mul {
                TraceOp::multiplier(self.distance(&mut rng, i))
            } else {
                TraceOp::INDEPENDENT
            };
            ops.push(op);
        }
        let t = Trace::new(ops);
        debug_assert!(t.validate().is_ok());
        t
    }

    /// Draw a dependence distance: 1 + geometric(tail), clamped to stay
    /// inside the trace.
    fn distance(&self, rng: &mut Rng, i: usize) -> u32 {
        let mut d = 1u32;
        while rng.chance(self.distance_tail) && d < 8 {
            d += 1;
        }
        d.min(i as u32)
    }
}

/// Tiny deterministic string hash (names → seed offsets).
fn fxhash(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipesim::trace::DepKind;

    #[test]
    fn traces_match_profile_fractions() {
        for p in Profile::suite() {
            let t = p.generate(50_000, 7);
            t.validate().unwrap();
            let acc = t.dep_fraction(DepKind::Accumulate);
            let mul = t.dep_fraction(DepKind::Multiplier);
            assert!((acc - p.p_acc).abs() < 0.02, "{}: acc {acc:.3} vs {}", p.name, p.p_acc);
            assert!((mul - p.p_mul).abs() < 0.02, "{}: mul {mul:.3} vs {}", p.name, p.p_mul);
        }
    }

    #[test]
    fn deterministic_by_seed() {
        let p = Profile::suite()[0];
        let a = p.generate(1000, 42);
        let b = p.generate(1000, 42);
        assert_eq!(a.ops, b.ops);
        let c = p.generate(1000, 43);
        assert_ne!(a.ops, c.ops);
    }

    #[test]
    fn distances_have_geometric_tail() {
        let p = Profile { name: "t", p_acc: 1.0, p_mul: 0.0, distance_tail: 0.5 };
        let t = p.generate(20_000, 3);
        let mut d1 = 0;
        let mut d2plus = 0;
        for op in &t.ops {
            match op.dep {
                Some((1, _)) => d1 += 1,
                Some((_, _)) => d2plus += 1,
                None => {}
            }
        }
        // tail = 0.5 ⇒ roughly half the dependences at distance 1.
        let frac1 = d1 as f64 / (d1 + d2plus) as f64;
        assert!((frac1 - 0.5).abs() < 0.03, "frac at distance1: {frac1}");
    }

    #[test]
    fn suite_spans_acc_heavy_and_mul_heavy() {
        let suite = Profile::suite();
        assert!(suite.iter().any(|p| p.p_acc > 2.0 * p.p_mul));
        assert!(suite.iter().any(|p| p.p_mul > 2.0 * p.p_acc));
        // The aggregate leans accumulate-heavy, as the paper observes.
        let acc: f64 = suite.iter().map(|p| p.p_acc).sum();
        let mul: f64 = suite.iter().map(|p| p.p_mul).sum();
        assert!(acc > 1.5 * mul);
    }

    #[test]
    fn suite_names_unique() {
        let suite = Profile::suite();
        let mut names: Vec<&str> = suite.iter().map(|p| p.name).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), suite.len());
    }
}
