//! Pareto-frontier extraction over (performance ↑, energy ↓) points —
//! how FPGen picks the designs worth fabricating (Fig. 3's curves are
//! frontiers of exactly this form).

/// A point in the 2-D objective space: maximize `perf`, minimize
/// `energy`.
pub trait Objective {
    fn perf(&self) -> f64;
    fn energy(&self) -> f64;
}

impl Objective for (f64, f64) {
    fn perf(&self) -> f64 {
        self.0
    }
    fn energy(&self) -> f64 {
        self.1
    }
}

/// Does `a` dominate `b` (no worse in both, strictly better in one)?
pub fn dominates<T: Objective>(a: &T, b: &T) -> bool {
    let ge = a.perf() >= b.perf() && a.energy() <= b.energy();
    let strict = a.perf() > b.perf() || a.energy() < b.energy();
    ge && strict
}

/// Indices of the Pareto-optimal points, sorted by ascending performance.
///
/// O(n log n): sort by perf descending (energy ascending as tiebreak),
/// sweep keeping the running energy minimum.
pub fn frontier<T: Objective>(points: &[T]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..points.len()).collect();
    idx.sort_by(|&i, &j| {
        points[j]
            .perf()
            .partial_cmp(&points[i].perf())
            .unwrap()
            .then(points[i].energy().partial_cmp(&points[j].energy()).unwrap())
    });
    let mut out = Vec::new();
    let mut best_energy = f64::INFINITY;
    let mut last_perf = f64::NAN;
    for &i in &idx {
        let e = points[i].energy();
        let p = points[i].perf();
        if e < best_energy {
            // Equal-perf duplicates: only the lowest-energy one survives
            // (it is first in sort order).
            if p != last_perf || out.is_empty() {
                out.push(i);
            }
            best_energy = e;
            last_perf = p;
        }
    }
    out.reverse(); // ascending perf
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn known_frontier() {
        // (perf, energy)
        let pts = vec![
            (1.0, 1.0), // frontier
            (2.0, 2.0), // frontier
            (1.5, 3.0), // dominated by (2,2)
            (3.0, 5.0), // frontier
            (0.5, 0.9), // frontier (lowest energy)
            (2.5, 5.0), // dominated by (3,5)
        ];
        let f = frontier(&pts);
        assert_eq!(f, vec![4, 0, 1, 3]);
    }

    #[test]
    fn frontier_has_no_dominated_point() {
        let mut rng = Rng::new(5);
        let pts: Vec<(f64, f64)> = (0..500).map(|_| (rng.f64() * 10.0, rng.f64() * 10.0)).collect();
        let f = frontier(&pts);
        assert!(!f.is_empty());
        for &i in &f {
            for (j, p) in pts.iter().enumerate() {
                if i != j {
                    assert!(!dominates(p, &pts[i]), "{j} dominates frontier member {i}");
                }
            }
        }
        // And every non-frontier point IS dominated by someone.
        for (j, p) in pts.iter().enumerate() {
            if !f.contains(&j) {
                assert!(
                    pts.iter().enumerate().any(|(k, q)| k != j && dominates(q, p)),
                    "non-frontier point {j} is undominated"
                );
            }
        }
    }

    #[test]
    fn frontier_sorted_and_monotone() {
        let mut rng = Rng::new(9);
        let pts: Vec<(f64, f64)> = (0..200).map(|_| (rng.f64(), rng.f64())).collect();
        let f = frontier(&pts);
        for w in f.windows(2) {
            assert!(pts[w[0]].perf() < pts[w[1]].perf());
            assert!(pts[w[0]].energy() < pts[w[1]].energy(), "frontier energy must rise with perf");
        }
    }

    #[test]
    fn duplicates_and_degenerate_inputs() {
        let f = frontier(&Vec::<(f64, f64)>::new());
        assert!(f.is_empty());
        let f = frontier(&[(1.0, 1.0)]);
        assert_eq!(f, vec![0]);
        // Exact duplicates: exactly one survives.
        let f = frontier(&[(1.0, 1.0), (1.0, 1.0), (1.0, 1.0)]);
        assert_eq!(f.len(), 1);
    }

    #[test]
    fn dominance_relation() {
        assert!(dominates(&(2.0, 1.0), &(1.0, 2.0)));
        assert!(!dominates(&(1.0, 2.0), &(2.0, 1.0)));
        assert!(!dominates(&(1.0, 1.0), &(1.0, 1.0))); // not strict
        assert!(dominates(&(1.0, 0.5), &(1.0, 1.0)));
    }
}
