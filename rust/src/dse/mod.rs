//! Design-space exploration: the FPGen sweep loop ([`sweep`]) and
//! Pareto-frontier extraction ([`pareto`]) that together regenerate the
//! tradeoff curves of Fig. 3 and Fig. 4.

pub mod pareto;
pub mod sweep;

pub use pareto::{dominates, frontier, Objective};
pub use sweep::{
    arch_space, arch_sweep, arch_sweep_measured, arch_sweep_measured_bb, voltage_bb_sweep,
    voltage_sweep, DsePoint,
};
