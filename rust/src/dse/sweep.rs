//! The FPGen design-space-exploration loop: architecture sweeps at a
//! fixed voltage (Fig. 3's triangle-marked curve) and voltage/body-bias
//! sweeps of a chosen design (the square-marked and BB curves).

use crate::arch::booth::BoothRadix;
use crate::arch::engine::{ActivityTrace, BatchExecutor, Fidelity, UnitDatapath};
use crate::arch::fp::Precision;
use crate::arch::generator::{FpuConfig, FpuKind, FpuUnit};
use crate::arch::tree::TreeKind;
use crate::bb::{run_energy_trace, BbPolicy};
use crate::energy::power::{evaluate, evaluate_measured, EfficiencyPoint};
use crate::energy::tech::{OperatingPoint, Technology};
use crate::timing;
use crate::workloads::throughput::{OperandMix, OperandStream, OperandTriple};
use crate::workloads::utilization::UtilizationProfile;

use super::pareto::Objective;

/// One evaluated design point.
#[derive(Debug, Clone)]
pub struct DsePoint {
    pub config: FpuConfig,
    pub eff: EfficiencyPoint,
    /// Measured phase-aware body-bias column: energy/op (pJ) of this
    /// design running a low-utilization measured trace under the
    /// adaptive V_BB policy (see [`arch_sweep_measured_bb`]). `None` for
    /// sweeps that did not execute traces.
    pub bb_adaptive_pj_per_op: Option<f64>,
}

impl Objective for DsePoint {
    /// Fig. 3's x-axis: compute density.
    fn perf(&self) -> f64 {
        self.eff.gflops_per_mm2
    }
    /// Fig. 3's y-axis: energy per FLOP.
    fn energy(&self) -> f64 {
        self.eff.pj_per_flop
    }
}

/// Enumerate the architecture neighbourhood FPGen explores for one unit
/// family: pipeline depth × Booth radix × reduction tree (with pipe
/// splits derived from the stage budget, as the generator does).
pub fn arch_space(precision: Precision, kind: FpuKind) -> Vec<FpuConfig> {
    let mut out = Vec::new();
    let stage_range = match kind {
        FpuKind::Fma => 3..=9,
        FpuKind::Cma => 4..=10,
    };
    for stages in stage_range {
        for booth in [BoothRadix::Booth2, BoothRadix::Booth3] {
            for tree in [TreeKind::Wallace, TreeKind::Array, TreeKind::Zm] {
                let (mul_pipe, add_pipe) = match kind {
                    FpuKind::Fma => ((stages / 2).max(1), 0),
                    FpuKind::Cma => {
                        let mul = ((stages - 1) / 2).max(1);
                        let add = stages - 1 - mul;
                        (mul, add)
                    }
                };
                let cfg = FpuConfig { precision, kind, booth, tree, stages, mul_pipe, add_pipe, forwarding: true };
                if cfg.validate().is_ok() {
                    out.push(cfg);
                }
            }
        }
    }
    out
}

/// Evaluate every architecture in the space at one operating point
/// (FPGen's fixed-1V sweep). Inoperable points are skipped.
pub fn arch_sweep(
    precision: Precision,
    kind: FpuKind,
    tech: &Technology,
    op: OperatingPoint,
) -> Vec<DsePoint> {
    arch_space(precision, kind)
        .into_iter()
        .filter_map(|cfg| {
            let unit = FpuUnit::generate(&cfg);
            evaluate(&unit, tech, op, 1.0)
                .map(|eff| DsePoint { config: cfg, eff, bb_adaptive_pj_per_op: None })
        })
        .collect()
}

/// Data-driven architecture sweep: every candidate executes a shared
/// operand sample through the unified engine before being scored, so the
/// energy axis uses *measured* datapath activity instead of the fixed
/// average-activity assumption.
///
/// The sample runs **word-level** by default (`fidelity`): results stay
/// bit-identical while the per-3:2-row gate simulation — the only
/// expensive part of scoring ~42 designs × thousands of operands — is
/// skipped, which is what makes activity-aware Fig. 3 / Fig. 4
/// regeneration tractable. Pass [`Fidelity::GateLevel`] to score from
/// true toggle counts instead (an order of magnitude slower).
/// [`Fidelity::WordSimd`] scores identically to word level — tracked
/// runs observe the same word-level activity — so either word tier is a
/// valid choice here.
pub fn arch_sweep_measured(
    precision: Precision,
    kind: FpuKind,
    tech: &Technology,
    op: OperatingPoint,
    sample_ops: usize,
    fidelity: Fidelity,
    seed: u64,
) -> Vec<DsePoint> {
    let triples: Vec<OperandTriple> =
        OperandStream::new(precision, OperandMix::Finite, seed).batch(sample_ops);
    let exec = BatchExecutor::auto();
    // One result buffer serves every candidate: ~42 designs × thousands
    // of operands stay allocation-free through `run_tracked_into`.
    let mut bits = vec![0u64; triples.len()];
    arch_space(precision, kind)
        .into_iter()
        .filter_map(|cfg| {
            let unit = FpuUnit::generate(&cfg);
            let dp = UnitDatapath::new(&unit, fidelity);
            let activity =
                exec.run_tracked_into(&dp, &triples, &mut bits).expect("buffer sized above");
            evaluate_measured(&unit, tech, op, 1.0, &activity)
                .map(|eff| DsePoint { config: cfg, eff, bb_adaptive_pj_per_op: None })
        })
        .collect()
}

/// Phase-aware data-driven sweep: like [`arch_sweep_measured`], but every
/// candidate additionally runs a **measured low-utilization trace** (the
/// shared operand sample woven into a `utilization`-duty schedule at
/// `window_slots`-slot windows) and is scored under the adaptive
/// body-bias policy — the `bb_adaptive_pj_per_op` column. This is the
/// sweep behind `fpmax sweep --bb adaptive`: designs whose leakage looms
/// large at low occupancy separate from those whose dynamic energy
/// dominates, which a run-level average cannot show.
#[allow(clippy::too_many_arguments)]
pub fn arch_sweep_measured_bb(
    precision: Precision,
    kind: FpuKind,
    tech: &Technology,
    op: OperatingPoint,
    sample_ops: usize,
    fidelity: Fidelity,
    seed: u64,
    window_slots: u64,
    utilization: f64,
) -> Vec<DsePoint> {
    assert!(utilization > 0.0 && utilization <= 1.0);
    // Bursts of ~10 windows (capped at the op budget) keep the idle gaps
    // long relative to the bias settle time at the default grids; the
    // active cycles across the whole schedule equal `sample_ops`.
    let burst = (window_slots * 10).min(sample_ops.max(1) as u64);
    let total = ((sample_ops as f64 / utilization).round() as u64).max(burst);
    let profile = UtilizationProfile::duty(utilization, burst, total);
    arch_space(precision, kind)
        .into_iter()
        .filter_map(|cfg| {
            let unit = FpuUnit::generate(&cfg);
            let dp = UnitDatapath::new(&unit, fidelity);
            let mut stream = OperandStream::new(precision, OperandMix::Finite, seed);
            let trace = ActivityTrace::record_profile(&dp, &profile, window_slots, &mut stream);
            let eff = evaluate_measured(&unit, tech, op, 1.0, &trace.aggregate())?;
            let freq = timing::timing(&cfg, tech, op)?.freq_ghz;
            let adaptive = run_energy_trace(
                &unit,
                tech,
                op.vdd,
                BbPolicy::adaptive_nominal(freq),
                &trace,
            )?;
            Some(DsePoint {
                config: cfg,
                eff,
                bb_adaptive_pj_per_op: Some(adaptive.pj_per_op),
            })
        })
        .collect()
}

/// Voltage sweep of one design: evaluate at each V_DD (fixed V_BB).
pub fn voltage_sweep(
    cfg: &FpuConfig,
    tech: &Technology,
    vdds: &[f64],
    vbb: f64,
) -> Vec<EfficiencyPoint> {
    let unit = FpuUnit::generate(cfg);
    vdds.iter()
        .filter_map(|&vdd| evaluate(&unit, tech, OperatingPoint::new(vdd, vbb), 1.0))
        .collect()
}

/// Joint (V_DD, V_BB) sweep: evaluate the full grid and keep the Pareto
/// frontier in (performance, energy/FLOP) — the paper's "V_DD and BB"
/// curve. This is where body bias actually pays at full utilization:
/// forward bias buys frequency, letting V_DD drop at matched performance
/// so dynamic energy falls by V² while the leakage penalty stays small.
pub fn voltage_bb_sweep(
    cfg: &FpuConfig,
    tech: &Technology,
    vdds: &[f64],
    vbbs: &[f64],
) -> Vec<EfficiencyPoint> {
    let unit = FpuUnit::generate(cfg);
    let mut points: Vec<EfficiencyPoint> = Vec::new();
    for &vdd in vdds {
        for &vbb in vbbs {
            let op = OperatingPoint::new(vdd, vbb);
            if !tech.valid(op) {
                continue;
            }
            if let Some(p) = evaluate(&unit, tech, op, 1.0) {
                points.push(p);
            }
        }
    }
    let objs: Vec<(f64, f64)> = points.iter().map(|p| (p.gflops_per_mm2, p.pj_per_flop)).collect();
    let idx = super::pareto::frontier(&objs);
    idx.into_iter().map(|i| points[i]).collect()
}

/// The standard sweep grids used by the Fig. 3 / Fig. 4 benches.
pub fn default_vdd_grid() -> Vec<f64> {
    (0..=17).map(|i| 0.45 + 0.04 * i as f64).collect() // 0.45 … 1.13 V
}

pub fn default_vbb_grid() -> Vec<f64> {
    (0..=8).map(|i| -0.8 + 0.4 * i as f64).collect() // −0.8 … 2.4 → clamped by tech
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::pareto::frontier;

    #[test]
    fn arch_space_includes_fabricated_points() {
        let space = arch_space(Precision::Single, FpuKind::Fma);
        let sp_fma = FpuConfig::sp_fma();
        assert!(
            space.iter().any(|c| c.stages == sp_fma.stages
                && c.booth == sp_fma.booth
                && c.tree == sp_fma.tree),
            "the fabricated SP FMA must be in the explored space"
        );
        // 7 stage counts × 2 booth × 3 trees.
        assert_eq!(space.len(), 42);
        for cfg in &space {
            cfg.validate().unwrap();
        }
    }

    #[test]
    fn arch_sweep_produces_spread() {
        let tech = Technology::fdsoi28();
        let pts = arch_sweep(Precision::Single, FpuKind::Fma, &tech, OperatingPoint::new(1.0, 0.0));
        assert!(pts.len() > 30);
        let e_min = pts.iter().map(|p| p.energy()).fold(f64::INFINITY, f64::min);
        let e_max = pts.iter().map(|p| p.energy()).fold(0.0, f64::max);
        // The design space spans a real energy range (>1.5×).
        assert!(e_max / e_min > 1.5, "{e_min} … {e_max}");
    }

    #[test]
    fn frontier_of_sweep_is_small_and_clean() {
        let tech = Technology::fdsoi28();
        let pts = arch_sweep(Precision::Single, FpuKind::Fma, &tech, OperatingPoint::new(1.0, 0.0));
        let f = frontier(&pts);
        assert!(!f.is_empty() && f.len() < pts.len());
        // Frontier energies rise with performance.
        for w in f.windows(2) {
            assert!(pts[w[0]].eff.pj_per_flop < pts[w[1]].eff.pj_per_flop);
        }
    }

    #[test]
    fn voltage_sweep_monotone_frequency() {
        let tech = Technology::fdsoi28();
        let pts = voltage_sweep(&FpuConfig::sp_fma(), &tech, &default_vdd_grid(), 1.2);
        assert!(pts.len() > 10);
        for w in pts.windows(2) {
            assert!(w[1].freq_ghz > w[0].freq_ghz, "freq must rise with vdd");
        }
    }

    #[test]
    fn bb_frontier_dominates_fixed_bias() {
        // Every fixed-bias point must be matched-or-beaten by the joint
        // frontier: some frontier point has ≥ its performance at ≤ its
        // energy.
        let tech = Technology::fdsoi28();
        let vdds = default_vdd_grid();
        let joint = voltage_bb_sweep(&FpuConfig::sp_fma(), &tech, &vdds, &default_vbb_grid());
        let fixed = voltage_sweep(&FpuConfig::sp_fma(), &tech, &vdds, 0.0);
        for f in &fixed {
            let covered = joint.iter().any(|j| {
                j.gflops_per_mm2 >= f.gflops_per_mm2 * 0.999
                    && j.pj_per_flop <= f.pj_per_flop * 1.001
            });
            assert!(covered, "fixed-bias point at vdd {} undominated", f.op.vdd);
        }
        // The frontier is sorted by ascending performance.
        for w in joint.windows(2) {
            assert!(w[0].gflops_per_mm2 < w[1].gflops_per_mm2);
        }
    }

    #[test]
    fn measured_sweep_covers_space_and_tracks_static_sweep() {
        let tech = Technology::fdsoi28();
        let op = OperatingPoint::new(1.0, 0.0);
        let pts = arch_sweep(Precision::Single, FpuKind::Fma, &tech, op);
        let measured = arch_sweep_measured(
            Precision::Single,
            FpuKind::Fma,
            &tech,
            op,
            500,
            Fidelity::WordLevel,
            42,
        );
        // Same candidate set, same frequencies; only the energy axis may
        // shift (by the bounded activity scale).
        assert_eq!(measured.len(), pts.len());
        for (m, p) in measured.iter().zip(&pts) {
            assert_eq!(m.config, p.config);
            assert!((m.eff.freq_ghz - p.eff.freq_ghz).abs() < 1e-12);
            let ratio = m.eff.pj_per_flop / p.eff.pj_per_flop;
            assert!((0.3..=2.5).contains(&ratio), "{:?}: ratio {ratio}", m.config);
        }
    }

    #[test]
    fn measured_sweep_word_simd_matches_word_level() {
        // The lane-batched tier must not shift a single DSE score: same
        // bits, same word-level activity observables, same energy axis.
        let tech = Technology::fdsoi28();
        let op = OperatingPoint::new(1.0, 0.0);
        let word = arch_sweep_measured(
            Precision::Single,
            FpuKind::Cma,
            &tech,
            op,
            400,
            Fidelity::WordLevel,
            9,
        );
        let simd = arch_sweep_measured(
            Precision::Single,
            FpuKind::Cma,
            &tech,
            op,
            400,
            Fidelity::WordSimd,
            9,
        );
        assert_eq!(word.len(), simd.len());
        for (w, s) in word.iter().zip(&simd) {
            assert_eq!(w.config, s.config);
            assert_eq!(w.eff.pj_per_flop, s.eff.pj_per_flop, "{:?}", w.config);
            assert_eq!(w.eff.gflops_per_mm2, s.eff.gflops_per_mm2);
        }
    }

    #[test]
    fn measured_bb_sweep_fills_phase_aware_column() {
        let tech = Technology::fdsoi28();
        let op = OperatingPoint::new(0.7, 1.2);
        let pts = arch_sweep_measured_bb(
            Precision::Single,
            FpuKind::Fma,
            &tech,
            op,
            2_000,
            Fidelity::WordLevel,
            42,
            1_000,
            0.1,
        );
        assert_eq!(pts.len(), arch_space(Precision::Single, FpuKind::Fma).len());
        for p in &pts {
            let col = p.bb_adaptive_pj_per_op.expect("bb column populated");
            assert!(col.is_finite() && col > 0.0, "{:?}: {col}", p.config);
            // At 10% occupancy the adaptive energy/op must exceed the
            // full-utilization dynamic energy (leakage and stalls only
            // add) — a cheap sanity bound that catches unit slips.
            assert!(col > 0.1 * p.eff.pj_per_flop, "{:?}", p.config);
        }
        // The plain measured sweep leaves the column empty.
        let plain = arch_sweep_measured(
            Precision::Single,
            FpuKind::Fma,
            &tech,
            op,
            500,
            Fidelity::WordLevel,
            42,
        );
        assert!(plain.iter().all(|p| p.bb_adaptive_pj_per_op.is_none()));
    }

    #[test]
    fn dp_space_mirrors_sp() {
        let space = arch_space(Precision::Double, FpuKind::Cma);
        assert!(space.iter().any(|c| {
            let dp = FpuConfig::dp_cma();
            c.stages == dp.stages && c.booth == dp.booth && c.tree == dp.tree
        }));
    }
}
