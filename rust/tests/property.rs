//! Property-based tests (in-tree driver: `util::check_cases` — proptest
//! is unavailable offline). Each property runs thousands of generated
//! cases and reports the seed + case index on failure for exact replay.

use fpmax::arch::fp::{decode, Class, Format, Precision};
use fpmax::arch::generator::{FpuConfig, FpuUnit};
use fpmax::arch::multiplier::{multiply, MultiplierConfig};
use fpmax::arch::rounding::RoundMode;
use fpmax::arch::softfloat;
use fpmax::arch::booth::BoothRadix;
use fpmax::arch::tree::TreeKind;
use fpmax::pipesim::{simulate, LatencyModel, Trace, TraceOp};
use fpmax::util::{check_cases, Rng};

fn same32(x: u32, y: u32) -> bool {
    x == y || (f32::from_bits(x).is_nan() && f32::from_bits(y).is_nan())
}

fn same64(x: u64, y: u64) -> bool {
    x == y || (f64::from_bits(x).is_nan() && f64::from_bits(y).is_nan())
}

#[test]
fn prop_softfloat_fma_equals_hardware_sp() {
    check_cases(0x51f0_0001, 200_000, |r: &mut Rng| (r.f32_any(), r.f32_any(), r.f32_any()), |&(a, b, c)| {
        let got = softfloat::fma(
            Format::SP, RoundMode::NearestEven, a as u64, b as u64, c as u64,
        ).bits as u32;
        let want = f32::from_bits(a).mul_add(f32::from_bits(b), f32::from_bits(c)).to_bits();
        if same32(got, want) {
            Ok(())
        } else {
            Err(format!("{got:#x} vs {want:#x}"))
        }
    });
}

#[test]
fn prop_softfloat_fma_equals_hardware_dp() {
    check_cases(0xd1f0_0002, 200_000, |r: &mut Rng| (r.f64_any(), r.f64_any(), r.f64_any()), |&(a, b, c)| {
        let got = softfloat::fma(Format::DP, RoundMode::NearestEven, a, b, c).bits;
        let want = f64::from_bits(a).mul_add(f64::from_bits(b), f64::from_bits(c)).to_bits();
        if same64(got, want) {
            Ok(())
        } else {
            Err(format!("{got:#x} vs {want:#x}"))
        }
    });
}

#[test]
fn prop_directed_modes_bracket_rne() {
    // RD ≤ RNE ≤ RU as reals, and RZ has minimal magnitude — on finite
    // results.
    check_cases(3, 50_000, |r: &mut Rng| (r.f32_operand(), r.f32_operand(), r.f32_operand()), |&(a, b, c)| {
        let run = |m| f32::from_bits(
            softfloat::fma(Format::SP, m, a as u64, b as u64, c as u64).bits as u32,
        );
        let (rn, rz, ru, rd) = (
            run(RoundMode::NearestEven),
            run(RoundMode::TowardZero),
            run(RoundMode::TowardPositive),
            run(RoundMode::TowardNegative),
        );
        if [rn, rz, ru, rd].iter().any(|v| v.is_nan()) {
            return Ok(());
        }
        if rd <= rn && rn <= ru && rz.abs() <= rn.abs().max(rd.abs().min(ru.abs())) && rd <= rz && rz <= ru {
            Ok(())
        } else {
            Err(format!("rd={rd:e} rz={rz:e} rn={rn:e} ru={ru:e}"))
        }
    });
}

#[test]
fn prop_structural_multiplier_exact_all_configs() {
    let configs: Vec<MultiplierConfig> = [BoothRadix::Booth2, BoothRadix::Booth3]
        .iter()
        .flat_map(|&booth| {
            [TreeKind::Wallace, TreeKind::Array, TreeKind::Zm]
                .iter()
                .flat_map(move |&tree| {
                    [24u32, 53].iter().map(move |&m| MultiplierConfig { sig_bits: m, booth, tree })
                })
                .collect::<Vec<_>>()
        })
        .collect();
    check_cases(7, 20_000, |r: &mut Rng| {
        let i = r.below(configs.len() as u64) as usize;
        let m = configs[i].sig_bits;
        let mask = (1u64 << m) - 1;
        (i, r.next_u64() & mask, r.next_u64() & mask)
    }, |&(i, x, y)| {
        let cfg = &configs[i];
        let r = multiply(cfg, x, y);
        if r.product(cfg) == x as u128 * y as u128 {
            Ok(())
        } else {
            Err(format!("cfg {cfg:?}"))
        }
    });
}

#[test]
fn prop_fma_units_fused_semantics() {
    let sp = FpuUnit::generate(&FpuConfig::sp_fma());
    let dp = FpuUnit::generate(&FpuConfig::dp_fma());
    check_cases(11, 50_000, |r: &mut Rng| (r.f32_any(), r.f64_any()), |&(s_bits, d_bits)| {
        // Re-derive three operands from the two seeds deterministically.
        let (a, b, c) = (s_bits, s_bits.rotate_left(13), s_bits.rotate_right(7));
        let got = sp.fmac(a as u64, b as u64, c as u64).bits as u32;
        let want = f32::from_bits(a).mul_add(f32::from_bits(b), f32::from_bits(c)).to_bits();
        if !same32(got, want) {
            return Err(format!("sp {got:#x} vs {want:#x}"));
        }
        let (a, b, c) = (d_bits, d_bits.rotate_left(31), d_bits.rotate_right(17));
        let got = dp.fmac(a, b, c).bits;
        let want = f64::from_bits(a).mul_add(f64::from_bits(b), f64::from_bits(c)).to_bits();
        if !same64(got, want) {
            return Err(format!("dp {got:#x} vs {want:#x}"));
        }
        Ok(())
    });
}

#[test]
fn prop_cma_units_cascade_semantics() {
    let sp = FpuUnit::generate(&FpuConfig::sp_cma());
    let dp = FpuUnit::generate(&FpuConfig::dp_cma());
    check_cases(13, 50_000, |r: &mut Rng| (r.f32_any(), r.f64_any()), |&(s_bits, d_bits)| {
        let (a, b, c) = (s_bits, s_bits.wrapping_mul(3), s_bits.wrapping_add(0x9e37));
        let got = sp.fmac(a as u64, b as u64, c as u64).bits as u32;
        let want = (f32::from_bits(a) * f32::from_bits(b) + f32::from_bits(c)).to_bits();
        if !same32(got, want) {
            return Err(format!("sp cascade {got:#x} vs {want:#x}"));
        }
        let (a, b, c) = (d_bits, d_bits.wrapping_mul(3), d_bits.wrapping_add(0x9e37_79b9));
        let got = dp.fmac(a, b, c).bits;
        let want = (f64::from_bits(a) * f64::from_bits(b) + f64::from_bits(c)).to_bits();
        if !same64(got, want) {
            return Err(format!("dp cascade {got:#x} vs {want:#x}"));
        }
        Ok(())
    });
}

#[test]
fn prop_pipesim_issue_order_and_data_readiness() {
    // Invariants on random valid traces: (1) penalty ≥ 0 and bounded by
    // the worst tap; (2) cycles ≥ ops + drain − 1; (3) forwarding can
    // only help.
    let unit = FpuUnit::generate(&FpuConfig::dp_cma());
    let mut nofwd_cfg = FpuConfig::dp_cma();
    nofwd_cfg.forwarding = false;
    let nofwd = FpuUnit::generate(&nofwd_cfg);
    let (lat, lat_nofwd) = (LatencyModel::of(&unit), LatencyModel::of(&nofwd));
    check_cases(17, 2_000, |r: &mut Rng| {
        let n = 20 + r.below(200) as usize;
        let ops: Vec<TraceOp> = (0..n)
            .map(|i| {
                if i == 0 || r.chance(0.4) {
                    TraceOp::INDEPENDENT
                } else {
                    let d = 1 + r.below(i.min(6) as u64) as u32;
                    if r.chance(0.6) {
                        TraceOp::accumulate(d)
                    } else {
                        TraceOp::multiplier(d)
                    }
                }
            })
            .collect();
        Trace::new(ops)
    }, |trace| {
        trace.validate().map_err(|e| e.to_string())?;
        let sim = simulate(&lat, trace);
        let max_tap = lat.to_mul.max(lat.to_add) as f64;
        if sim.avg_penalty < 0.0 || sim.avg_penalty > max_tap {
            return Err(format!("penalty {} out of range", sim.avg_penalty));
        }
        if sim.cycles < trace.len() as u64 + lat.full as u64 - 1 {
            return Err(format!("cycles {} below floor", sim.cycles));
        }
        let sim2 = simulate(&lat_nofwd, trace);
        if sim2.avg_penalty + 1e-12 < sim.avg_penalty {
            return Err("forwarding hurt".into());
        }
        Ok(())
    });
}

#[test]
fn prop_decode_encode_roundtrip() {
    check_cases(19, 100_000, |r: &mut Rng| (r.f32_any(), r.f64_any()), |&(s, d)| {
        for (fmt, bits) in [(Format::SP, s as u64), (Format::DP, d)] {
            let dec = decode(fmt, bits);
            match dec.class {
                Class::Zero => {
                    if fmt.zero(dec.sign) != bits & fmt.storage_mask() {
                        return Err(format!("zero roundtrip {bits:#x}"));
                    }
                }
                Class::Subnormal | Class::Normal => {
                    let back = fpmax::arch::fp::encode_finite(fmt, dec.sign, dec.exp, dec.sig);
                    if back != bits & fmt.storage_mask() {
                        return Err(format!("{bits:#x} → {back:#x}"));
                    }
                }
                _ => {}
            }
        }
        Ok(())
    });
}

#[test]
fn prop_fmac_activity_consistency() {
    // Activity records must be internally consistent: nonzero digits ≤
    // digits, special ops do no tree work.
    let unit = FpuUnit::generate(&FpuConfig::sp_fma());
    check_cases(23, 30_000, |r: &mut Rng| (r.f32_any(), r.f32_any(), r.f32_any()), |&(a, b, c)| {
        let (_, act) = unit.fmac_mode(RoundMode::NearestEven, a as u64, b as u64, c as u64);
        if act.nonzero_digits > act.digits {
            return Err("digit count inconsistency".into());
        }
        if act.special && act.tree_fa_ops != 0 {
            return Err("special op did datapath work".into());
        }
        if !act.special && act.digits == 0 {
            return Err("finite op with no booth digits".into());
        }
        Ok(())
    });
}

#[test]
fn prop_chip_routing_and_batching() {
    // Chip-level invariants under random programs: every executed FMAC
    // lands in the result RAM in order, and cycle counts are the sum of
    // per-burst issue distances plus drains.
    use fpmax::chip::{FpMaxChip, Instruction, UnitSel, BANK_PROGRAM, BANK_RESULT, BANK_STIM_A, BANK_STIM_B, BANK_STIM_C};
    check_cases(29, 200, |r: &mut Rng| {
        let bursts: Vec<(u8, u16, u16)> = (0..(1 + r.below(4)))
            .map(|_| {
                (
                    r.below(4) as u8,
                    r.below(32) as u16,
                    (1 + r.below(32)) as u16,
                )
            })
            .collect();
        (r.next_u64(), bursts)
    }, |(seed, bursts)| {
        let mut chip = FpMaxChip::new(128);
        let mut rng = Rng::new(*seed);
        let data: Vec<u64> = (0..128).map(|_| rng.f32_operand() as u64).collect();
        {
            let mut port = chip.jtag();
            port.load_bank(BANK_STIM_A, &data).map_err(|e| e.to_string())?;
            port.load_bank(BANK_STIM_B, &data).map_err(|e| e.to_string())?;
            port.load_bank(BANK_STIM_C, &data).map_err(|e| e.to_string())?;
            let prog: Vec<u64> = bursts
                .iter()
                .map(|&(u, base, count)| {
                    let unit = match u {
                        0 => UnitSel::DpCma,
                        1 => UnitSel::DpFma,
                        2 => UnitSel::SpCma,
                        _ => UnitSel::SpFma,
                    };
                    Instruction::fmac_burst(unit, base.min(96), count.min(32)).encode() as u64
                })
                .chain(std::iter::once(0))
                .collect();
            port.load_bank(BANK_PROGRAM, &prog).map_err(|e| e.to_string())?;
        }
        let stats = chip.run().map_err(|e| e.to_string())?;
        let want_ops: u64 = bursts.iter().map(|&(_, _, c)| c.min(32) as u64).sum();
        if stats.ops != want_ops {
            return Err(format!("ops {} vs {}", stats.ops, want_ops));
        }
        if stats.results_written != want_ops {
            return Err("results not dense in result RAM".into());
        }
        if stats.cycles < want_ops {
            return Err("cycle count below issue floor".into());
        }
        // Result RAM contents are readable and in order.
        let back = chip.jtag().read_bank(BANK_RESULT, want_ops as usize).map_err(|e| e.to_string())?;
        if back.len() != want_ops as usize {
            return Err("readback length".into());
        }
        Ok(())
    });
}

#[test]
fn prop_energy_model_monotonicity() {
    use fpmax::energy::power::evaluate;
    use fpmax::energy::tech::{OperatingPoint, Technology};
    let tech = Technology::fdsoi28();
    let units: Vec<FpuUnit> = FpuConfig::fpmax_units().iter().map(FpuUnit::generate).collect();
    check_cases(31, 5_000, |r: &mut Rng| {
        (
            r.below(4) as usize,
            0.5 + r.f64() * 0.5,        // vdd in [0.5, 1.0)
            -0.5 + r.f64() * 1.5,       // vbb in [-0.5, 1.0)
            0.05 + r.f64() * 0.9,       // utilization
        )
    }, |&(i, vdd, vbb, util)| {
        let unit = &units[i];
        let op = OperatingPoint::new(vdd, vbb);
        let Some(p) = evaluate(unit, &tech, op, util) else { return Ok(()) };
        // Raising vdd at fixed bias must raise frequency and dynamic power.
        if let Some(q) = evaluate(unit, &tech, OperatingPoint::new(vdd + 0.05, vbb), util) {
            if q.freq_ghz <= p.freq_ghz {
                return Err(format!("freq not monotone at {vdd:.2}"));
            }
            if q.power.dynamic_mw <= p.power.dynamic_mw {
                return Err("dynamic power not monotone in vdd".into());
            }
        }
        // Forward bias raises leakage.
        if let Some(q) = evaluate(unit, &tech, OperatingPoint::new(vdd, vbb + 0.2), util) {
            if q.power.leakage_mw <= p.power.leakage_mw {
                return Err("leakage not monotone in vbb".into());
            }
        }
        // Utilization scales dynamic power proportionally.
        if let Some(q) = evaluate(unit, &tech, op, util / 2.0) {
            let ratio = p.power.dynamic_mw / q.power.dynamic_mw;
            if (ratio - 2.0).abs() > 1e-6 {
                return Err(format!("dyn power not ∝ util: {ratio}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_pareto_frontier_sound() {
    use fpmax::dse::pareto::{dominates, frontier};
    check_cases(37, 2_000, |r: &mut Rng| {
        let n = 2 + r.below(60) as usize;
        (0..n).map(|_| (r.f64() * 10.0, r.f64() * 10.0)).collect::<Vec<(f64, f64)>>()
    }, |pts| {
        let f = frontier(pts);
        if f.is_empty() {
            return Err("empty frontier".into());
        }
        for &i in &f {
            for (j, p) in pts.iter().enumerate() {
                if i != j && dominates(p, &pts[i]) {
                    return Err(format!("frontier member {i} dominated by {j}"));
                }
            }
        }
        Ok(())
    });
}
