//! Fault-tolerance properties of the serve fleet: shard death is
//! contained (supervisor quarantines, salvages, respawns, re-admits by
//! probe), accounting is conserved across shard incarnations, producers
//! on the retry path get exactly one result, and a no-fault chaos run
//! is bit-identical to the plain router path on the same op stream.

use std::time::{Duration, Instant};

use fpmax::arch::engine::{Datapath, Fidelity, UnitDatapath};
use fpmax::arch::fp::Precision;
use fpmax::arch::generator::{FpuConfig, FpuUnit};
use fpmax::coordinator::{serve_chaos, RoutedLoad};
use fpmax::runtime::chaos::{fnv1a_fold, FaultKind, FaultPlan, FaultTrigger, FNV_OFFSET};
use fpmax::runtime::router::{
    RetryPolicy, RouterConfig, ServeRouter, ServiceClass, ShardHealth, ShardSpec, WorkloadClass,
};
use fpmax::runtime::serve::{ServeConfig, ServeError, ServeQueue};
use fpmax::util::Rng;
use fpmax::workloads::throughput::{OperandMix, OperandStream};

fn spec(config: FpuConfig, tier: Fidelity, workers: usize, window: usize) -> ShardSpec {
    let mut serve = ServeConfig::nominal(&config, true).expect("nominal serve config");
    serve.workers = workers;
    serve.window_ops = window;
    ShardSpec { config, tier, serve }
}

fn sp_pair(tier: Fidelity, window: usize) -> Vec<ShardSpec> {
    vec![
        spec(FpuConfig::sp_cma(), tier, 1, window),
        spec(FpuConfig::sp_fma(), tier, 1, window),
    ]
}

/// Fast supervision for tests: tight poll, small probe.
fn fast_supervision(workers_budget: usize) -> RouterConfig {
    let mut cfg = RouterConfig::no_spill(workers_budget);
    cfg.supervision_poll = Duration::from_micros(200);
    cfg.probe_ops = 32;
    cfg
}

/// Block until shard `idx` is Healthy with at least `respawns`
/// incarnation swaps behind it.
fn wait_respawned(router: &ServeRouter, idx: usize, respawns: u64) {
    let deadline = Instant::now() + Duration::from_secs(30);
    while Instant::now() < deadline {
        if router.shard_respawns(idx) >= respawns
            && router.shard_health(idx) == ShardHealth::Healthy
        {
            return;
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    panic!(
        "shard {idx} did not recover: respawns {} health {:?}",
        router.shard_respawns(idx),
        router.shard_health(idx)
    );
}

#[test]
fn shard_respawns_and_serves_again_under_every_tier() {
    // The supervision loop end-to-end, per fidelity tier: kill the
    // latency shard's dispatcher mid-service, wait for quarantine →
    // salvage → respawn → probe re-admission, then verify the respawned
    // shard serves bit-exact results and the final report carries both
    // incarnations' accounting.
    for (tier, n) in [
        (Fidelity::GateLevel, 96usize),
        (Fidelity::WordLevel, 512),
        (Fidelity::WordSimd, 512),
    ] {
        let specs = sp_pair(tier, 128);
        let router = ServeRouter::start(&specs, fast_supervision(2)).unwrap();
        let class =
            WorkloadClass { precision: Precision::Single, service: ServiceClass::Latency };
        let dp = UnitDatapath::generate(&specs[0].config, tier);
        let mut stream = OperandStream::new(Precision::Single, OperandMix::Finite, 17);

        // First incarnation serves.
        let triples = stream.batch(n);
        let mut want = vec![0u64; n];
        dp.fmac_batch(&triples, &mut want);
        let (idx, ticket) = router.submit(class, tier, triples).unwrap();
        assert_eq!(idx, 0, "latency affinity is the CMA shard");
        assert_eq!(ticket.wait().unwrap(), want, "{tier:?}");

        // Kill it; the supervisor must bring incarnation 2 up.
        router.shard_handle(0).inject_fault().unwrap();
        wait_respawned(&router, 0, 1);

        // Second incarnation serves the same class, bit-exact.
        let triples = stream.batch(n);
        let mut want = vec![0u64; n];
        dp.fmac_batch(&triples, &mut want);
        let (idx, ticket) = router.submit(class, tier, triples).unwrap();
        assert_eq!(idx, 0, "recovered shard takes its affinity class back");
        assert_eq!(ticket.wait().unwrap(), want, "{tier:?} after respawn");

        let report = router.finish().unwrap();
        let shard = &report.shards[0];
        assert_eq!(shard.respawns, 1, "{tier:?}");
        assert_eq!(shard.prior.len(), 1, "one dead incarnation salvaged");
        // Both incarnations' ops are in the shard total: the killed
        // incarnation's submission + the respawn's (probe + submission).
        assert_eq!(shard.total_ops(), shard.prior[0].ops + shard.report.ops);
        assert!(shard.total_ops() >= 2 * n as u64, "{tier:?}");
        assert!(report.conservation_ok(), "{tier:?}");
        assert_eq!(report.crosscheck_mismatches(), 0);
        assert!(report.bb_gate_ok(), "{tier:?}: dead incarnation must stay exact-on-received");
    }
}

#[test]
fn fault_plan_runs_are_deterministic_given_serialized_submission() {
    // Same seed ⇒ same plan ⇒ (under serialized submission, which
    // removes scheduler interleaving) bit-identical result streams and
    // identical deterministic report fields on every shard, dead
    // incarnations included.
    let tier = Fidelity::WordSimd;
    let total: u64 = 6_000;
    let plan = FaultPlan::kill_each_shard_once(99, 2, total);
    assert_eq!(plan, FaultPlan::kill_each_shard_once(99, 2, total));

    let run = || {
        let specs = sp_pair(tier, 128);
        let router = ServeRouter::start(&specs, fast_supervision(2)).unwrap();
        let class =
            WorkloadClass { precision: Precision::Single, service: ServiceClass::Latency };
        let mut stream = OperandStream::new(Precision::Single, OperandMix::Finite, 5);
        let mut rng = Rng::new(7);
        let mut checksum = FNV_OFFSET;
        let mut submitted = 0u64;
        let mut fault_at = plan.faults.iter().peekable();
        while submitted < total {
            if let Some(f) = fault_at.peek() {
                let FaultTrigger::SubmittedOps(at) = f.trigger else {
                    panic!("op-anchored kill plans never carry trace-slot triggers")
                };
                if submitted >= at {
                    let FaultKind::KillDispatcher { shard } = f.kind else {
                        panic!("kill plan only schedules kills")
                    };
                    let before = router.shard_respawns(shard);
                    router.shard_handle(shard).inject_fault().unwrap();
                    wait_respawned(&router, shard, before + 1);
                    fault_at.next();
                }
            }
            let n = (64 + rng.below(128)) as usize;
            let triples = stream.batch(n);
            // Serialized: wait every ticket before the next submit, so
            // batch boundaries (hence windows, hence energies) are
            // schedule-independent.
            let (_, ticket) = router.submit(class, tier, triples).unwrap();
            for b in ticket.wait().unwrap() {
                checksum = fnv1a_fold(checksum, b);
            }
            submitted += n as u64;
        }
        let report = router.finish().unwrap();
        let shards: Vec<_> = report
            .shards
            .iter()
            .map(|s| {
                (
                    s.respawns,
                    s.prior.len(),
                    s.total_ops(),
                    s.class_counts,
                    s.report.submissions,
                    s.report.batches,
                    s.prior.iter().map(|p| (p.ops, p.submissions, p.batches)).collect::<Vec<_>>(),
                    s.total_energy(),
                )
            })
            .collect();
        (checksum, report.submissions, report.ops, shards)
    };

    let a = run();
    let b = run();
    assert_eq!(a.0, b.0, "result bit streams diverged between same-seed runs");
    assert_eq!(a.1, b.1);
    assert_eq!(a.2, b.2);
    assert_eq!(a.3, b.3, "surviving-shard reports diverged between same-seed runs");
}

#[test]
fn worker_panic_is_contained_and_the_pool_stays_usable() {
    // A panicking lane kernel errors its own batch's tickets; the
    // dispatcher, its persistent pool, and every later submission
    // survive — no respawn involved.
    let cfg = FpuConfig::sp_fma();
    let unit = FpuUnit::generate(&cfg);
    let mut scfg = ServeConfig::nominal(&cfg, true).unwrap();
    scfg.workers = 2;
    scfg.window_ops = 128;
    let queue = ServeQueue::start(&unit, scfg).unwrap();
    let dp = UnitDatapath::new(&unit, Fidelity::WordSimd);
    let mut stream = OperandStream::new(cfg.precision, OperandMix::Finite, 23);

    let n = 300usize;
    let triples = stream.batch(n);
    let mut want = vec![0u64; n];
    dp.fmac_batch(&triples, &mut want);
    let t1 = queue.submit(Fidelity::WordSimd, triples).unwrap();
    assert_eq!(t1.wait().unwrap(), want);

    queue.handle().inject_worker_panic().unwrap();
    let doomed = stream.batch(n);
    let t2 = queue.submit(Fidelity::WordSimd, doomed).unwrap();
    let err = t2.wait().expect_err("the poisoned batch's ticket must error");
    assert_eq!(ServeError::classify(&err), Some(ServeError::WorkerPanic));
    assert!(ServeError::classify(&err).unwrap().retryable());

    // Same dispatcher, same pool, next batch is clean.
    assert!(queue.dispatcher_alive(), "worker panic must not kill the dispatcher");
    let triples = stream.batch(n);
    let mut want = vec![0u64; n];
    dp.fmac_batch(&triples, &mut want);
    let t3 = queue.submit(Fidelity::WordSimd, triples).unwrap();
    assert_eq!(t3.wait().unwrap(), want);

    let report = queue.finish().unwrap();
    assert_eq!(report.failed_batches, 1);
    assert_eq!(report.errored_submissions, 1);
    assert_eq!(report.submissions, 2);
    assert_eq!(report.ops, 2 * n as u64, "the poisoned batch is never counted as executed");
    assert_eq!(report.crosscheck_mismatches, 0);
    assert!(report.bb_gate_ok());
}

#[test]
fn retry_after_quarantine_delivers_exactly_one_result() {
    // Single-shard fleet, so the class has no failover sibling: while
    // the shard is down the resilient path must retry (backoff) until
    // the respawn re-admits it — and deliver the result exactly once.
    let tier = Fidelity::WordSimd;
    let specs = vec![spec(FpuConfig::sp_fma(), tier, 1, 128)];
    let router = ServeRouter::start(&specs, fast_supervision(1)).unwrap();
    let class = WorkloadClass { precision: Precision::Single, service: ServiceClass::Bulk };
    let dp = UnitDatapath::generate(&specs[0].config, tier);

    router.shard_handle(0).inject_fault().unwrap();
    // Observe the outage before submitting, so at least one attempt
    // must fail (the salvage-respawn-probe round trip is far longer
    // than the gap between this check and the first route).
    let deadline = Instant::now() + Duration::from_secs(30);
    while router.shard_health(0) == ShardHealth::Healthy {
        assert!(Instant::now() < deadline, "supervisor never quarantined the dead shard");
        std::thread::sleep(Duration::from_micros(100));
    }

    let n = 400usize;
    let triples = OperandStream::new(Precision::Single, OperandMix::Finite, 31).batch(n);
    let mut want = vec![0u64; n];
    dp.fmac_batch(&triples, &mut want);
    let outcome = router
        .submit_with_retry(
            class,
            tier,
            &triples,
            Some(Duration::from_secs(30)),
            RetryPolicy::bounded(200, Duration::from_millis(1), Duration::from_millis(20)),
        )
        .expect("retry must outlast the quarantine window");
    assert_eq!(outcome.bits, want, "exactly-once delivery, bit-exact");
    assert_eq!(outcome.shard, 0);
    assert!(outcome.retries >= 1, "the outage was observed before the first attempt");

    // The old incarnation's pressure counter died with it; the live
    // handle's is balanced back to zero once the fleet drains.
    let deadline = Instant::now() + Duration::from_secs(10);
    while router.shard_pressure(0) != 0 {
        assert!(Instant::now() < deadline, "pressure never drained to zero");
        std::thread::sleep(Duration::from_millis(1));
    }

    let report = router.finish().unwrap();
    assert!(report.shards[0].respawns >= 1);
    assert!(report.conservation_ok());
    assert_eq!(report.crosscheck_mismatches(), 0);
}

#[test]
fn kill_every_shard_mid_load_passes_all_chaos_gates() {
    // The acceptance drill: a seeded plan kills every shard of the
    // 4-shard Table-1 fleet once under routed load. Zero hangs, zero
    // lost ops, crosscheck clean on surviving work, every fault fired,
    // every shard respawned, and fleet ops/energy/latency accounting
    // exact-sum across incarnations.
    let tier = Fidelity::WordSimd;
    let window = 256;
    let specs: Vec<ShardSpec> =
        FpuConfig::fpmax_units().into_iter().map(|c| spec(c, tier, 1, window)).collect();
    let total_ops = 48_000usize;
    let plan = FaultPlan::kill_each_shard_once(4242, specs.len(), total_ops as u64);
    let load = RoutedLoad {
        total_ops,
        producers_per_class: 1,
        sub_ops: 512,
        duty: 1.0,
        seed: 4242,
    };
    let outcome = serve_chaos(
        &specs,
        fast_supervision(4),
        tier,
        load,
        &plan,
        Duration::from_secs(60),
        RetryPolicy::bounded(40, Duration::from_millis(1), Duration::from_millis(25)),
    )
    .unwrap();
    let r = &outcome.report;
    assert!(r.zero_hung(), "hung: {} subs / {} ops", r.producer.hung_subs, r.producer.hung_ops);
    assert!(
        r.zero_lost(),
        "lost ops: {} completed + {} errored != {} submitted",
        r.producer.completed_ops,
        r.producer.errored_ops,
        r.producer.submitted_ops
    );
    assert!(r.crosscheck_clean(), "{} crosscheck mismatches", r.crosscheck_mismatches);
    assert!(r.coverage_ok(), "{} of {} faults fired", r.faults_fired, r.faults_planned);
    assert_eq!(r.kills, 4);
    assert!(r.respawns >= 4, "every killed shard must respawn, saw {}", r.respawns);
    assert!(r.conservation_ok, "fleet accounting must be exact-sum across incarnations");
    assert!(r.gates_ok());
    // Ops conservation is also visible bottom-up: shard incarnation ops
    // sum exactly to the fleet total.
    let bottom_up: u64 = outcome.fleet.shards.iter().map(|s| s.total_ops()).sum();
    assert_eq!(bottom_up, outcome.fleet.ops);
}

#[test]
fn no_fault_chaos_is_bit_identical_to_the_plain_router_path() {
    // The control arm of the acceptance criterion: an empty plan, the
    // same seeds — the resilient path's checksums must equal a plain
    // PR-5-style submit/wait mirror of the identical op stream. Full
    // Table-1 fleet so every class has an affinity shard.
    let tier = Fidelity::WordSimd;
    let specs: Vec<ShardSpec> =
        FpuConfig::fpmax_units().into_iter().map(|c| spec(c, tier, 1, 256)).collect();
    let total_ops = 8_000usize;
    let seed = 1234u64;
    let load =
        RoutedLoad { total_ops, producers_per_class: 1, sub_ops: 256, duty: 1.0, seed };
    let outcome = serve_chaos(
        &specs,
        fast_supervision(4),
        tier,
        load,
        &FaultPlan::none(seed),
        Duration::from_secs(60),
        RetryPolicy::none(),
    )
    .unwrap();
    let r = &outcome.report;
    assert!(r.gates_ok());
    assert_eq!(r.respawns, 0, "nothing may die in the control run");
    assert_eq!(r.rerouted_on_failure, 0);
    assert_eq!(r.producer.errored_subs, 0);
    assert_eq!(r.producer.retries, 0);

    // Mirror: the plain submit/wait router path over the very same
    // per-producer streams (serialized per producer — placement is
    // pressure-independent with spill off, so interleaving cannot
    // change where work lands or what bits come back).
    let classes = WorkloadClass::ALL;
    let producers = classes.len();
    let router = ServeRouter::start(&specs, fast_supervision(4)).unwrap();
    let mut mirror = Vec::with_capacity(producers);
    for p in 0..producers {
        let class = classes[p % classes.len()];
        let share = total_ops / producers + usize::from(p < total_ops % producers);
        // producer_seeds(seed, p), inlined: the chaos producers and the
        // routed serve workload share this exact derivation.
        let stream_seed = seed.wrapping_add(0x9E37_79B9_7F4A_7C15u64.wrapping_mul(p as u64 + 1));
        let size_seed = seed ^ (((p as u64 + 1) << 32) | 0xA5);
        let mut stream = OperandStream::new(class.precision, OperandMix::Finite, stream_seed);
        let mut rng = Rng::new(size_seed);
        let mut checksum = FNV_OFFSET;
        let mut left = share;
        while left > 0 {
            let span = (256 / 2 + rng.below(256) as usize).clamp(1, left);
            let triples = stream.batch(span);
            let (_, ticket) = router.submit(class, tier, triples).unwrap();
            for b in ticket.wait().unwrap() {
                checksum = fnv1a_fold(checksum, b);
            }
            left -= span;
        }
        mirror.push(checksum);
    }
    router.finish().unwrap();

    assert_eq!(
        outcome.report.producer.checksums, mirror,
        "no-fault chaos diverged from the plain router path"
    );
}
