//! Differential conformance fuzzing across the full tier stack.
//!
//! Four-way diff on every seeded triple: gate-level structural
//! simulation (reference) vs scalar word-level softfloat vs the
//! dispatching word-simd lane kernels vs the host CPU's own IEEE-754
//! hardware — five-way with the always-scalar lane reference when the
//! `simd` feature splits it from the dispatching path — six-way on the
//! small formats, whose packed-SWAR word engine joins the diff. Zero
//! mismatches are required on every fleet format (SP, DP, FP16, BF16,
//! FP8e4m3, FP8e5m2), all four op kinds, and both operand streams; any
//! disagreement fails with the minimized counterexamples rendered in
//! `edge_vectors.rs` format.
//!
//! Operand counts are sized for debug-build gate-level throughput; the
//! CI fuzz smoke (`fpmax fuzz`, release build) runs the same harness at
//! 200k operands per precision × kind.

use fpmax::arch::fuzz::{run_differential, standard_engines, FuzzConfig, OpKind, StreamKind};
use fpmax::arch::{Format, FpuConfig, FpuUnit, Precision};

fn units(fmt: Format) -> (FpuUnit, FpuUnit) {
    let precision = Precision::ALL
        .into_iter()
        .find(|p| p.format() == fmt)
        .expect("every fleet format carries a precision tag");
    (
        FpuUnit::generate(&FpuConfig::fma_of(precision)),
        FpuUnit::generate(&FpuConfig::cma_of(precision)),
    )
}

#[test]
fn four_way_conformance_uniform_and_structured() {
    // The full format matrix: SP/DP plus every transprecision tier, all
    // four op kinds, both operand streams. Small formats additionally
    // carry the packed-SWAR engine inside `standard_engines`.
    for fmt in Format::all() {
        let (fma_unit, cma_unit) = units(fmt);
        let engines = standard_engines(&fma_unit, &cma_unit);
        for kind in OpKind::ALL {
            for (stream, seed) in [
                (StreamKind::UniformBits, 0x0D1F_0001u64),
                (StreamKind::Structured, 0x0D1F_0002u64),
            ] {
                let cfg = FuzzConfig::new(8_000, seed ^ fmt.sig_bits as u64, stream);
                let report = run_differential(fmt, kind, &engines, &cfg);
                assert!(
                    report.clean(),
                    "tier disagreement, sig_bits={} kind={} stream={:?}:\n{}",
                    fmt.sig_bits,
                    kind.name(),
                    stream,
                    report.render()
                );
                assert_eq!(report.executed, cfg.ops);
            }
        }
    }
}

#[test]
fn reports_are_seed_deterministic() {
    let fmt = Format::SP;
    let (fma_unit, cma_unit) = units(fmt);
    let engines = standard_engines(&fma_unit, &cma_unit);
    let cfg = FuzzConfig::new(2_000, 0xDE7E_0001, StreamKind::Structured);
    let r1 = run_differential(fmt, OpKind::Fma, &engines, &cfg);
    let r2 = run_differential(fmt, OpKind::Fma, &engines, &cfg);
    assert_eq!(r1.executed, r2.executed);
    assert_eq!(r1.render(), r2.render());
}

#[test]
fn counterexamples_render_in_edge_vector_format() {
    // Force a disagreement by diffing RNE against a deliberately
    // different reference stream length-1 shim: the host engine vs a
    // sign-flipped host. Exercises minimize + render end-to-end without
    // depending on any real bug existing.
    use fpmax::arch::fuzz::{host, Engine};
    let fmt = Format::SP;
    let engines = [
        Engine::new("host", true, move |k, a, b, c| host(fmt, k, a, b, c)),
        Engine::new("host-negated", true, move |k, a, b, c| {
            host(fmt, k, a, b, c) ^ fmt.sign_bit()
        }),
    ];
    let mut cfg = FuzzConfig::new(64, 1, StreamKind::UniformBits);
    cfg.max_counterexamples = 2;
    let report = run_differential(fmt, OpKind::Mul, &engines, &cfg);
    assert!(!report.clean());
    for ce in &report.counterexamples {
        let line = ce.render_edge_vector();
        assert!(line.starts_with("v(0x"), "bad corpus line: {line}");
        assert!(line.contains("// fuzz sp mul"), "bad provenance: {line}");
    }
}
