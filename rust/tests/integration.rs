//! Integration tests across modules: chip ⇄ golden model ⇄ pipesim ⇄
//! energy model, plus the PJRT runtime against the AOT artifacts when
//! they are built (`make artifacts`).

use fpmax::arch::fp::Precision;
use fpmax::arch::generator::{FpuConfig, FpuUnit};
use fpmax::arch::rounding::RoundMode;
use fpmax::chip::{
    expected_result, FpMaxChip, Instruction, Op, UnitSel, BANK_PROGRAM, BANK_RESULT, BANK_STIM_A,
    BANK_STIM_B, BANK_STIM_C,
};
use fpmax::coordinator;
use fpmax::runtime::Runtime;
use fpmax::workloads::throughput::{OperandMix, OperandStream};

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if p.join("sp_fmac.hlo.txt").exists() {
        Some(p)
    } else {
        None
    }
}

#[test]
fn chip_program_through_all_units_matches_golden() {
    let mut chip = FpMaxChip::new(256);
    for (sel, cfg) in [
        (UnitSel::DpCma, FpuConfig::dp_cma()),
        (UnitSel::DpFma, FpuConfig::dp_fma()),
        (UnitSel::SpCma, FpuConfig::sp_cma()),
        (UnitSel::SpFma, FpuConfig::sp_fma()),
    ] {
        let mut stream = OperandStream::new(cfg.precision, OperandMix::Anything, 0xBEEF);
        let triples = stream.batch(256);
        let a: Vec<u64> = triples.iter().map(|t| t.a).collect();
        let b: Vec<u64> = triples.iter().map(|t| t.b).collect();
        let c: Vec<u64> = triples.iter().map(|t| t.c).collect();
        {
            let mut port = chip.jtag();
            port.load_bank(BANK_STIM_A, &a).unwrap();
            port.load_bank(BANK_STIM_B, &b).unwrap();
            port.load_bank(BANK_STIM_C, &c).unwrap();
            let prog = [Instruction::fmac_burst(sel, 0, 256).encode() as u64, 0];
            port.load_bank(BANK_PROGRAM, &prog).unwrap();
        }
        chip.run().unwrap();
        let results = chip.jtag().read_bank(BANK_RESULT, 256).unwrap();
        let unit = chip.unit(sel);
        for i in 0..256 {
            let want = expected_result(unit, RoundMode::NearestEven, a[i], b[i], c[i], Op::Fmac);
            use fpmax::arch::fp::{decode, Class};
            let ok = results[i] == want
                || (decode(unit.format, results[i]).class == Class::Nan
                    && decode(unit.format, want).class == Class::Nan);
            assert!(ok, "{sel:?} op {i}: {:#x} vs {:#x}", results[i], want);
        }
    }
}

#[test]
fn chip_accumulation_program_obeys_bypass_timing() {
    // The accumulate burst must take to_add cycles per op, and the chip's
    // final value must equal a sequential cascade accumulation.
    let mut chip = FpMaxChip::new(64);
    let one = 1.0f64.to_bits();
    let xs: Vec<f64> = (1..=32).map(|i| i as f64 * 0.5).collect();
    let a = vec![one; 32];
    let b: Vec<u64> = xs.iter().map(|x| x.to_bits()).collect();
    let c = vec![0u64; 32];
    {
        let mut port = chip.jtag();
        port.load_bank(BANK_STIM_A, &a).unwrap();
        port.load_bank(BANK_STIM_B, &b).unwrap();
        port.load_bank(BANK_STIM_C, &c).unwrap();
        let prog = [Instruction::accumulate_burst(UnitSel::DpCma, 0, 32).encode() as u64, 0];
        port.load_bank(BANK_PROGRAM, &prog).unwrap();
    }
    let stats = chip.run().unwrap();
    let unit = chip.unit(UnitSel::DpCma);
    assert_eq!(
        stats.cycles,
        32 * unit.latency_to_add_input() as u64 + unit.latency_full() as u64
    );
    let results = chip.jtag().read_bank(BANK_RESULT, 32).unwrap();
    let mut acc = 0.0f64;
    for (i, x) in xs.iter().enumerate() {
        acc = 1.0 * x + acc; // cascade: two IEEE ops, matches f64 arith
        assert_eq!(f64::from_bits(results[i]), acc, "step {i}");
    }
}

#[test]
fn coordinator_verifies_every_unit_on_adversarial_operands() {
    for cfg in FpuConfig::fpmax_units() {
        let unit = FpuUnit::generate(&cfg);
        let mut s = OperandStream::new(cfg.precision, OperandMix::Anything, 1234);
        let r = coordinator::verify_datapath_only(&unit, &s.batch(20_000), 8);
        assert!(r.clean(), "{}: {:?}", cfg.name(), r.datapath_mismatches.first());
    }
}

#[test]
fn pjrt_artifacts_match_golden_model() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        return;
    };
    // Default builds carry the no-op runtime stub; only `--features pjrt`
    // can actually load artifacts, so a constructor error is a skip.
    let rt = match Runtime::cpu(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping: {e}");
            return;
        }
    };
    for (name, cfg) in [("sp_fmac", FpuConfig::sp_fma()), ("dp_fmac", FpuConfig::dp_fma())] {
        let artifact = rt.load_fmac(name, cfg.precision).expect("load");
        assert!(artifact.batch > 0);
        let unit = FpuUnit::generate(&cfg);
        let mut s = OperandStream::new(cfg.precision, OperandMix::Finite, 99);
        let triples = s.batch(artifact.batch + 17); // exercise tail padding
        let r = coordinator::verify_batch(&unit, &artifact, &triples, 4).expect("verify");
        assert!(r.clean(), "{name}: {:?}", r.artifact_mismatches.first());
        assert!(r.artifact_toggles > 0);
    }
}

#[test]
fn pjrt_artifact_handles_special_values() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts/ not built");
        return;
    };
    let rt = match Runtime::cpu(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping: {e}");
            return;
        }
    };
    let artifact = rt.load_fmac("sp_fmac", Precision::Single).expect("load");
    let unit = FpuUnit::generate(&FpuConfig::sp_fma());
    let mut s = OperandStream::new(Precision::Single, OperandMix::Anything, 7);
    let r = coordinator::verify_batch(&unit, &artifact, &s.batch(8192), 4).expect("verify");
    assert!(r.clean(), "{:?}", r.artifact_mismatches.first());
}

#[test]
fn jtag_is_the_slow_port() {
    // Fig. 5's premise: at-speed cycles per op ≈ 1, JTAG cycles per op ≫.
    let mut chip = FpMaxChip::new(128);
    let mut s = OperandStream::new(Precision::Single, OperandMix::Finite, 3);
    let triples = s.batch(128);
    let a: Vec<u64> = triples.iter().map(|t| t.a).collect();
    let tck = {
        let mut port = chip.jtag();
        port.load_bank(BANK_STIM_A, &a).unwrap();
        port.load_bank(BANK_STIM_B, &a).unwrap();
        port.load_bank(BANK_STIM_C, &a).unwrap();
        let prog = [Instruction::fmac_burst(UnitSel::SpFma, 0, 128).encode() as u64, 0];
        port.load_bank(BANK_PROGRAM, &prog).unwrap();
        port.tck_cycles
    };
    let stats = chip.run().unwrap();
    assert!(stats.cycles < 128 + 8, "at-speed: ~1 cycle/op");
    assert!(tck > 50 * stats.cycles, "JTAG must be orders slower: {tck} vs {}", stats.cycles);
}
