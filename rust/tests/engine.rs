//! Properties of the unified batched execution engine: batch execution
//! is bit-identical to scalar execution, fidelity tiers agree, sampled
//! gate-level cross-checks stay clean, and activity accumulation is
//! worker-count invariant. All randomness is seeded (in-tree driver:
//! `util::check_cases`; proptest is unavailable offline).

use fpmax::arch::engine::{BatchExecutor, Datapath, Fidelity, UnitDatapath};
use fpmax::arch::generator::{FpuConfig, FpuUnit};
use fpmax::util::{check_cases, Rng};
use fpmax::workloads::throughput::{OperandMix, OperandStream, OperandTriple};

/// The seeded random streams the properties run on.
fn stream(cfg: &FpuConfig, mix: OperandMix, n: usize, seed: u64) -> Vec<OperandTriple> {
    OperandStream::new(cfg.precision, mix, seed).batch(n)
}

#[test]
fn prop_fmac_batch_equals_n_scalar_ops_all_presets() {
    // The issue's core property: for random streams on all four presets,
    // `fmac_batch` must be bit-identical to N× `fmac_one` — at every
    // fidelity tier, at several batch shapes that exercise the chunking
    // (and, for word-simd, the lane blocks plus their scalar remainder).
    for cfg in FpuConfig::fpmax_units() {
        for fidelity in [Fidelity::GateLevel, Fidelity::WordLevel, Fidelity::WordSimd] {
            let dp = UnitDatapath::generate(&cfg, fidelity);
            for (seed, n) in [(0xBA7C4 ^ cfg.stages as u64, 4_097usize), (99, 1_000), (7, 33)] {
                let triples = stream(&cfg, OperandMix::Anything, n, seed);
                let scalar: Vec<u64> =
                    triples.iter().map(|t| dp.fmac_one(t.a, t.b, t.c)).collect();
                let mut batch = vec![0u64; n];
                dp.fmac_batch(&triples, &mut batch);
                assert_eq!(
                    batch,
                    scalar,
                    "{} {fidelity:?} seed={seed} n={n}",
                    cfg.name()
                );
            }
        }
    }
}

#[test]
fn prop_executor_invariant_over_worker_counts() {
    // Parallel execution must not change a single bit, whatever the
    // worker count or remainder shape.
    let cfg = FpuConfig::dp_fma();
    let unit = FpuUnit::generate(&cfg);
    check_cases(0x5EED, 12, |r: &mut Rng| {
        (1 + r.below(64) as usize, 1 + r.below(3_000) as usize, r.next_u64())
    }, |&(workers, n, seed)| {
        let triples = stream(&cfg, OperandMix::Anything, n, seed);
        let want: Vec<u64> = triples.iter().map(|t| unit.fmac_one(t.a, t.b, t.c)).collect();
        let got = BatchExecutor::new(workers).run(&unit, &triples);
        if got == want {
            Ok(())
        } else {
            Err(format!("divergence at workers={workers} n={n}"))
        }
    });
}

#[test]
fn prop_word_level_sampled_crosscheck_clean_all_presets() {
    // The acceptance property behind Fidelity::WordLevel: sampled
    // gate-level cross-checks report zero mismatches on every preset.
    for cfg in FpuConfig::fpmax_units() {
        let unit = FpuUnit::generate(&cfg);
        let triples = stream(&cfg, OperandMix::Anything, 30_000, 0xF1DE11 ^ cfg.mul_pipe as u64);
        let (out, check) = BatchExecutor::auto().run_checked(&unit, &triples, 101);
        assert!(
            check.clean(),
            "{}: gate/word mismatch at {:?}",
            cfg.name(),
            check.mismatches
        );
        assert_eq!(check.sampled, triples.len().div_ceil(101));
        // And the word-level results really are the unit's semantics.
        let want = BatchExecutor::auto().run(&unit, &triples);
        assert_eq!(out, want, "{}", cfg.name());
    }
}

#[test]
fn prop_simd_equals_word_equals_gate_all_presets_all_mixes() {
    // The word-simd acceptance property: on every preset, over random
    // operand mixes including subnormal/NaN/Inf-heavy ones, the
    // lane-batched tier, the scalar word tier and the gate-level datapath
    // produce identical bits at every batch shape (odd lengths exercise
    // the scalar remainder after the lane blocks).
    for cfg in FpuConfig::fpmax_units() {
        let gate = UnitDatapath::generate(&cfg, Fidelity::GateLevel);
        let word = UnitDatapath::generate(&cfg, Fidelity::WordLevel);
        let simd = UnitDatapath::generate(&cfg, Fidelity::WordSimd);
        for mix in [OperandMix::Anything, OperandMix::SpecialHeavy, OperandMix::Finite] {
            for (seed, n) in [(0x51AD ^ cfg.stages as u64, 2_051usize), (3, 64), (19, 7)] {
                let triples = stream(&cfg, mix, n, seed);
                let mut got_word = vec![0u64; n];
                let mut got_simd = vec![0u64; n];
                word.fmac_batch(&triples, &mut got_word);
                simd.fmac_batch(&triples, &mut got_simd);
                for (i, t) in triples.iter().enumerate() {
                    let g = gate.fmac_one(t.a, t.b, t.c);
                    assert_eq!(
                        got_simd[i], g,
                        "{} {mix:?} n={n} slot {i}: simd vs gate (a={:#x} b={:#x} c={:#x})",
                        cfg.name(), t.a, t.b, t.c
                    );
                    assert_eq!(got_word[i], g, "{} {mix:?} n={n} slot {i}: word vs gate", cfg.name());
                }
            }
        }
    }
}

#[test]
fn prop_simd_executor_invariant_over_worker_counts() {
    // The chunk-pulling parallel path must be bit-invariant for the lane
    // tier too, whatever the worker count, chunk calibration, or
    // remainder shape.
    let cfg = FpuConfig::sp_cma();
    let simd = UnitDatapath::generate(&cfg, Fidelity::WordSimd);
    check_cases(0x51AD5EED, 10, |r: &mut Rng| {
        (1 + r.below(32) as usize, 1 + r.below(4_000) as usize, r.next_u64())
    }, |&(workers, n, seed)| {
        let triples = stream(&cfg, OperandMix::SpecialHeavy, n, seed);
        let want: Vec<u64> = triples.iter().map(|t| simd.fmac_one(t.a, t.b, t.c)).collect();
        let exec = BatchExecutor::new(workers);
        let mut got = vec![0u64; n];
        exec.run_into(&simd, &triples, &mut got).unwrap();
        if got != want {
            return Err(format!("first run diverged at workers={workers} n={n}"));
        }
        // Second run reuses the buffer and the persisted pool +
        // calibration.
        exec.run_into(&simd, &triples, &mut got).unwrap();
        if got != want {
            return Err(format!("calibrated rerun diverged at workers={workers} n={n}"));
        }
        Ok(())
    });
}

#[test]
fn prop_window_sums_equal_aggregate_all_tiers() {
    // Satellite property (a): for every fidelity tier, random window
    // widths and worker counts, the windowed trace's per-window sums
    // reproduce the aggregate ActivityAccumulator of the same run bit
    // for bit, and the parallel trace equals the serial trace exactly.
    for cfg in [FpuConfig::sp_fma(), FpuConfig::dp_cma()] {
        let unit = FpuUnit::generate(&cfg);
        for fidelity in [Fidelity::GateLevel, Fidelity::WordLevel, Fidelity::WordSimd] {
            let dp = UnitDatapath::new(&unit, fidelity);
            check_cases(
                0x717A ^ cfg.stages as u64,
                6,
                |r: &mut Rng| {
                    (
                        1 + r.below(12) as usize,        // workers
                        1 + r.below(2_500) as usize,     // ops
                        1 + r.below(700) as usize,       // window
                        r.next_u64(),
                    )
                },
                |&(workers, n, window, seed)| {
                    let triples = stream(&cfg, OperandMix::Anything, n, seed);
                    let serial = BatchExecutor::serial();
                    let (want_bits, want_acc) = serial.run_tracked(&dp, &triples);
                    let (ser_bits, ser_trace) = serial.run_windowed(&dp, &triples, window);
                    if ser_bits != want_bits {
                        return Err(format!("serial windowed bits diverged n={n} win={window}"));
                    }
                    if ser_trace.aggregate() != want_acc {
                        return Err(format!(
                            "serial window sums != aggregate ({fidelity:?} n={n} win={window})"
                        ));
                    }
                    let exec = BatchExecutor::new(workers);
                    let (bits, trace) = exec.run_windowed(&dp, &triples, window);
                    if bits != want_bits {
                        return Err(format!("parallel windowed bits diverged w={workers}"));
                    }
                    if trace != ser_trace {
                        return Err(format!(
                            "parallel trace != serial trace ({fidelity:?} w={workers} n={n} win={window})"
                        ));
                    }
                    if trace.total_slots() != n as u64 || trace.total_ops() != n as u64 {
                        return Err("trace slot accounting broken".into());
                    }
                    Ok(())
                },
            );
        }
    }
}

#[test]
fn tracked_and_untracked_runs_agree() {
    let cfg = FpuConfig::sp_cma();
    let unit = FpuUnit::generate(&cfg);
    let triples = stream(&cfg, OperandMix::Finite, 5_000, 3);
    let exec = BatchExecutor::new(6);
    let plain = exec.run(&unit, &triples);
    let (tracked, acc) = exec.run_tracked(&unit, &triples);
    assert_eq!(plain, tracked);
    assert_eq!(acc.ops, 5_000);
    assert!(acc.tree_fa_ops > 0);
}

#[test]
fn executor_edge_shapes() {
    let cfg = FpuConfig::sp_fma();
    let unit = FpuUnit::generate(&cfg);
    let exec = BatchExecutor::new(8);
    // Empty batch.
    assert!(exec.run(&unit, &[]).is_empty());
    let (out, acc) = exec.run_tracked(&unit, &[]);
    assert!(out.is_empty());
    assert_eq!(acc.ops, 0);
    // Single op, more workers than work.
    let t = stream(&cfg, OperandMix::Finite, 1, 1);
    let got = exec.run(&unit, &t);
    assert_eq!(got[0], unit.fmac_one(t[0].a, t[0].b, t[0].c));
}
