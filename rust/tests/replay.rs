//! Trace-replay properties of the routing experiment: same seed + same
//! trace ⇒ a bit-identical replay digest under either policy, static
//! kind-preserving runs fold per-tenant result checksums into that
//! digest, slot-anchored chaos fires against the replay clock, and the
//! op-stream harness refuses plans it cannot clock.

use std::sync::Arc;
use std::time::Duration;

use fpmax::arch::engine::Fidelity;
use fpmax::arch::generator::FpuConfig;
use fpmax::coordinator::{serve_chaos, serve_trace, ReplayOutcome, RoutedLoad};
use fpmax::runtime::chaos::FaultPlan;
use fpmax::runtime::router::{
    EnergyAware, RetryPolicy, RoutePolicy, RouterConfig, ShardSpec, StaticAffinity,
};
use fpmax::runtime::serve::ServeConfig;
use fpmax::runtime::trace::{Trace, TraceConfig, SMALL_TIERS};

fn spec(config: FpuConfig, tier: Fidelity, workers: usize, window: usize) -> ShardSpec {
    let mut serve = ServeConfig::nominal(&config, true).expect("nominal serve config");
    serve.workers = workers;
    serve.window_ops = window;
    ShardSpec { config, tier, serve }
}

fn table1_specs(tier: Fidelity, window: usize) -> Vec<ShardSpec> {
    FpuConfig::fpmax_units().into_iter().map(|c| spec(c, tier, 1, window)).collect()
}

/// Fast supervision for tests: tight poll, small probe.
fn fast_supervision(workers_budget: usize) -> RouterConfig {
    let mut cfg = RouterConfig::no_spill(workers_budget);
    cfg.supervision_poll = Duration::from_micros(200);
    cfg.probe_ops = 32;
    cfg
}

fn replay(
    trace: &Trace,
    policy: Arc<dyn RoutePolicy>,
    plan: &FaultPlan,
) -> ReplayOutcome {
    let tier = Fidelity::WordSimd;
    let specs = table1_specs(tier, 256);
    serve_trace(
        &specs,
        fast_supervision(4),
        tier,
        trace,
        policy,
        plan,
        Duration::from_secs(60),
        RetryPolicy::bounded(200, Duration::from_micros(200), Duration::from_millis(10)),
    )
    .unwrap()
}

#[test]
fn static_replay_is_bit_identical_and_folds_result_checksums() {
    // Kind-preserving configuration (static policy, spill off, empty
    // plan): the digest covers the per-tenant result checksums too, and
    // two replays of the same trace agree on every digested bit.
    let trace = Trace::generate(TraceConfig::preset("uniform", 11, 4_000).unwrap()).unwrap();
    let plan = FaultPlan::none(11);
    let a = replay(&trace, Arc::new(StaticAffinity), &plan).report;
    let b = replay(&trace, Arc::new(StaticAffinity), &plan).report;

    assert!(a.results_in_digest, "static + no spill + no faults must digest result bits");
    assert_eq!(a.digest, b.digest, "same seed + same trace must be bit-identical");
    assert_eq!(a.producer.checksums, b.producer.checksums);
    assert_eq!(a.producer.checksums.len(), trace.config.tenants);

    assert!(a.gates_ok(), "ledger/crosscheck/conservation gates");
    assert_eq!(a.trace_fingerprint, trace.fingerprint);
    assert_eq!(a.events, trace.events.len());
    assert_eq!(a.producer.submitted_ops, trace.total_ops());
    assert_eq!(a.class_ops.iter().sum::<u64>(), trace.total_ops());
    assert_eq!(a.class_ops, trace.class_ops());
    assert_eq!(a.misrouted, 0, "static policy, spill off");
    assert_eq!(a.policy_routed, 0, "static policy never places on a cost score");
    assert_eq!(a.admission_denied, 0);
    assert_eq!(a.policy_name, "static");
}

#[test]
fn energy_aware_replay_keeps_the_ledger_digest_stable() {
    // Cross-kind placement legitimately changes result bits, so the
    // dynamic arm's digest covers the ledger invariants only — and THAT
    // must still be bit-identical across same-trace replays, faults or
    // not. The diurnal-skew preset is the shape the policy exists for.
    let trace =
        Trace::generate(TraceConfig::preset("diurnal-skew", 23, 6_000).unwrap()).unwrap();
    let plan = FaultPlan::none(23);
    let a = replay(&trace, Arc::new(EnergyAware::nominal()), &plan).report;
    let b = replay(&trace, Arc::new(EnergyAware::nominal()), &plan).report;

    assert!(!a.results_in_digest, "a cost-scoring policy may place cross-kind");
    assert_eq!(a.digest, b.digest, "ledger digest must survive placement freedom");
    assert!(a.gates_ok());
    assert_eq!(a.misrouted, 0, "deliberate placements are policy_routed, never misrouted");
    assert_eq!(a.producer.submitted_ops, trace.total_ops());
    assert_eq!(a.policy_name, "energy-aware");
    // Placement itself is load-dependent and not asserted here; the
    // dominance verdict on this preset is the replay bench's job.
}

#[test]
fn transprecision_replay_is_deterministic_across_the_format_fleet() {
    // The transprecision preset draws every class of the 12-class
    // matrix, so the fleet carries a CMA + FMA shard per small format
    // next to the Table-1 four. Static policy, spill off, no faults:
    // the replay digest (result checksums included) must be
    // bit-identical across a double run, the ledger must balance to
    // the trace's exact budget, and every class must land on-affinity.
    let tier = Fidelity::WordSimd;
    let mut specs = table1_specs(tier, 256);
    for tierp in SMALL_TIERS {
        specs.push(spec(FpuConfig::cma_of(tierp), tier, 1, 256));
        specs.push(spec(FpuConfig::fma_of(tierp), tier, 1, 256));
    }
    let trace =
        Trace::generate(TraceConfig::preset("transprecision", 31, 8_000).unwrap()).unwrap();
    let plan = FaultPlan::none(31);
    let run = || {
        serve_trace(
            &specs,
            fast_supervision(specs.len()),
            tier,
            &trace,
            Arc::new(StaticAffinity),
            &plan,
            Duration::from_secs(60),
            RetryPolicy::bounded(200, Duration::from_micros(200), Duration::from_millis(10)),
        )
        .unwrap()
        .report
    };
    let a = run();
    let b = run();

    assert!(a.results_in_digest, "static + no spill + no faults must digest result bits");
    assert_eq!(a.digest, b.digest, "same seed + same trace must be bit-identical");
    assert_eq!(a.producer.checksums, b.producer.checksums);
    assert!(a.gates_ok(), "ledger/crosscheck/conservation gates");
    assert_eq!(a.trace_fingerprint, trace.fingerprint);
    assert_eq!(a.producer.submitted_ops, trace.total_ops());
    assert_eq!(a.class_ops, trace.class_ops());
    assert_eq!(a.misrouted, 0, "static policy, spill off");
    // The preset's whole point: every small-tier class (latency AND
    // bulk per format, so the small CMA shards work too, not just the
    // FMA bulk path) really carried traffic.
    assert!(
        a.class_ops[4..].iter().all(|&n| n > 0),
        "every transprecision class must see ops, got {:?}",
        a.class_ops
    );
}

#[test]
fn slot_anchored_faults_fire_under_replay_and_pass_the_chaos_gates() {
    // A trace-slot-anchored kill of every shard composes with the
    // replay clock: every fault fires, every shard respawns, and the
    // ledger still balances to the trace's exact op budget.
    let tier = Fidelity::WordSimd;
    let specs = table1_specs(tier, 256);
    let trace =
        Trace::generate(TraceConfig::preset("uniform", 77, 24_000).unwrap()).unwrap();
    let plan =
        FaultPlan::kill_each_shard_once_at_slots(77, specs.len(), trace.last_slot().max(1));
    assert!(plan.needs_replay_clock());
    let outcome = serve_trace(
        &specs,
        fast_supervision(4),
        tier,
        &trace,
        Arc::new(StaticAffinity),
        &plan,
        Duration::from_secs(60),
        RetryPolicy::bounded(200, Duration::from_millis(1), Duration::from_millis(25)),
    )
    .unwrap();
    let r = &outcome.report;
    assert!(r.coverage_ok(), "{} of {} slot faults fired", r.faults_fired, r.faults_planned);
    assert_eq!(r.faults_planned, specs.len());
    assert!(r.respawns >= specs.len() as u64, "every killed shard must respawn");
    assert!(r.gates_ok());
    assert!(!r.results_in_digest, "faulted runs never digest result bits");
    assert_eq!(r.producer.submitted_ops, trace.total_ops());
    let bottom_up: u64 = outcome.fleet.shards.iter().map(|s| s.total_ops()).sum();
    assert_eq!(bottom_up, outcome.fleet.ops);
}

#[test]
fn the_op_stream_harness_rejects_slot_anchored_plans() {
    // serve_chaos has no replay clock, so a trace-slot plan would hang
    // its injector forever — it must be rejected at entry instead.
    let tier = Fidelity::WordSimd;
    let specs = table1_specs(tier, 256);
    let plan = FaultPlan::kill_each_shard_once_at_slots(5, specs.len(), 1_000);
    let load =
        RoutedLoad { total_ops: 1_000, producers_per_class: 1, sub_ops: 128, duty: 1.0, seed: 5 };
    let err = serve_chaos(
        &specs,
        fast_supervision(4),
        tier,
        load,
        &plan,
        Duration::from_secs(10),
        RetryPolicy::none(),
    )
    .expect_err("an op-count harness cannot clock trace-slot triggers");
    assert!(
        err.to_string().contains("trace-slot"),
        "rejection must name the axis mismatch, got: {err}"
    );
}
