//! Allocation accounting for the engine hot path: repeated
//! `BatchExecutor` runs into a reused caller-provided buffer must be
//! **zero-allocation** after warmup on the serial path, and must never
//! allocate proportionally to the batch size on the parallel path (the
//! only parallel allocations are the O(workers) scoped-thread
//! bookkeeping).
//!
//! Counted via a global-allocator shim — this test binary's allocator
//! wraps `System` with atomic counters, so any hidden `Vec`/`collect()`
//! on the hot path shows up as a hard failure.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use fpmax::arch::engine::{BatchExecutor, Datapath, Fidelity, UnitDatapath};
use fpmax::arch::generator::{FpuConfig, FpuUnit};
use fpmax::workloads::throughput::{OperandMix, OperandStream};

struct CountingAlloc;

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);
static ALLOC_BYTES: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(new_size as u64, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

/// Allocation calls and bytes attributable to `f`.
fn allocations<F: FnOnce()>(f: F) -> (u64, u64) {
    let c0 = ALLOC_CALLS.load(Ordering::Relaxed);
    let b0 = ALLOC_BYTES.load(Ordering::Relaxed);
    f();
    (
        ALLOC_CALLS.load(Ordering::Relaxed) - c0,
        ALLOC_BYTES.load(Ordering::Relaxed) - b0,
    )
}

#[test]
fn serial_batch_reuse_is_allocation_free_after_warmup() {
    let unit = FpuUnit::generate(&FpuConfig::sp_fma());
    let word = UnitDatapath::new(&unit, Fidelity::WordLevel);
    let simd = UnitDatapath::new(&unit, Fidelity::WordSimd);
    let triples =
        OperandStream::new(fpmax::arch::Precision::Single, OperandMix::Anything, 42).batch(20_000);
    let mut out = vec![0u64; triples.len()];
    let exec = BatchExecutor::serial();

    // Warmup: first touches of lazy TLS / libstd internals.
    exec.run_into(&word, &triples, &mut out).unwrap();
    exec.run_into(&simd, &triples, &mut out).unwrap();
    let mut acc = fpmax::arch::ActivityAccumulator::default();

    let (calls, bytes) = allocations(|| {
        for _ in 0..8 {
            exec.run_into(&simd, &triples, &mut out).unwrap();
            exec.run_into(&word, &triples, &mut out).unwrap();
            acc.merge(&exec.run_tracked_into(&word, &triples, &mut out).unwrap());
        }
    });
    assert_eq!(
        (calls, bytes),
        (0, 0),
        "serial engine hot path allocated: {calls} calls / {bytes} bytes"
    );
    assert_eq!(acc.ops, 8 * triples.len() as u64);
    // The results are real (paranoia against the loop being optimized out).
    assert_eq!(out[7], simd.fmac_one(triples[7].a, triples[7].b, triples[7].c));
}

#[test]
fn parallel_batch_reuse_allocations_do_not_scale_with_batch_size() {
    let unit = FpuUnit::generate(&FpuConfig::sp_fma());
    let simd = UnitDatapath::new(&unit, Fidelity::WordSimd);
    let triples =
        OperandStream::new(fpmax::arch::Precision::Single, OperandMix::Finite, 7).batch(200_000);
    let mut out = vec![0u64; triples.len()];
    let exec = BatchExecutor::new(4);

    // Warmup calibrates the chunk size and spawns the persistent pool.
    exec.run_into(&simd, &triples, &mut out).unwrap();

    let (_, bytes) = allocations(|| {
        exec.run_into(&simd, &triples, &mut out).unwrap();
    });
    // A 200k-op batch would need 1.6 MB if the executor still collect()ed
    // results; post-warmup pool dispatch is down to condvar signalling.
    assert!(
        bytes < 256 * 1024,
        "parallel run allocated {bytes} bytes for a 200k-op batch — \
         something on the hot path is materializing per-op state"
    );
}

#[test]
fn window_ring_publish_pop_allocation_free() {
    // The serve layer's engine→controller ring: after construction,
    // publish and pop allocate NOTHING — including the overflow path,
    // where surplus windows merge into the producer-side pending window
    // instead of growing anything.
    use fpmax::arch::engine::{window_ring, ActivityAccumulator, ActivityWindow};
    let (mut p, mut c) = window_ring(8);
    let w = ActivityWindow {
        slots: 64,
        acc: ActivityAccumulator { ops: 64, digits: 512, ..ActivityAccumulator::default() },
    };
    // Warmup (first touches of anything lazy).
    p.publish(w);
    let _ = c.pop();

    let mut received = 0u64;
    let mut slots = 0u64;
    let (calls, bytes) = allocations(|| {
        for round in 0..100u32 {
            // Overfill: 24 publishes into 8 slots, forcing coalescing.
            for _ in 0..24 {
                p.publish(w);
            }
            // Drain; skip some rounds so the pending window also gets
            // exercised across publish calls.
            if round % 3 != 2 {
                while let Some(e) = c.pop() {
                    received += 1;
                    slots += e.window.slots;
                }
            }
        }
        while let Some(e) = c.pop() {
            received += 1;
            slots += e.window.slots;
        }
    });
    assert_eq!(
        (calls, bytes),
        (0, 0),
        "window ring publish/pop allocated: {calls} calls / {bytes} bytes"
    );
    assert!(received > 0);
    // The pending window may still hold coalesced slots (close() would
    // flush it); everything else arrived intact.
    assert!(slots <= 100 * 24 * 64);
    assert_eq!(slots % 64, 0);
}

#[test]
fn parallel_batch_zero_alloc_after_pool_warmup() {
    // The persistent-pool guarantee: once the pool threads exist and the
    // chunk size is calibrated, parallel runs allocate NOTHING — job
    // dispatch is an epoch bump plus condvar signalling, the workers pull
    // chunks off a stack-held atomic cursor, and tracked merges fold into
    // stack-held accumulators.
    let unit = FpuUnit::generate(&FpuConfig::sp_fma());
    let word = UnitDatapath::new(&unit, Fidelity::WordLevel);
    let simd = UnitDatapath::new(&unit, Fidelity::WordSimd);
    let triples =
        OperandStream::new(fpmax::arch::Precision::Single, OperandMix::Finite, 9).batch(100_000);
    let mut out = vec![0u64; triples.len()];
    let exec = BatchExecutor::new(4);

    // Warmup: spawns the pool, calibrates, and touches every lazy path
    // (untracked + tracked) once.
    exec.run_into(&simd, &triples, &mut out).unwrap();
    exec.run_into(&word, &triples, &mut out).unwrap();
    let _ = exec.run_tracked_into(&word, &triples, &mut out).unwrap();

    let mut acc = fpmax::arch::ActivityAccumulator::default();
    let (calls, bytes) = allocations(|| {
        for _ in 0..4 {
            exec.run_into(&simd, &triples, &mut out).unwrap();
            exec.run_into(&word, &triples, &mut out).unwrap();
            acc.merge(&exec.run_tracked_into(&word, &triples, &mut out).unwrap());
        }
    });
    assert_eq!(
        (calls, bytes),
        (0, 0),
        "parallel engine hot path allocated after pool warmup: {calls} calls / {bytes} bytes"
    );
    assert_eq!(acc.ops, 4 * triples.len() as u64);
    assert_eq!(out[3], simd.fmac_one(triples[3].a, triples[3].b, triples[3].c));
}
