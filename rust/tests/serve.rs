//! Integration properties of the streaming serve layer: results stay
//! bit-identical to the batch engine under any producer interleaving,
//! and the streamed body-bias controller is bit-identical to the
//! post-hoc pass — across all three fidelity tiers, with idle phases
//! woven in, overflow included. (All randomness is seeded; the
//! *interleaving* of producer threads is genuinely nondeterministic,
//! which is the point: the invariants must hold for every schedule the
//! OS happens to produce.)

use fpmax::arch::engine::{BatchExecutor, Datapath, Fidelity, UnitDatapath};
use fpmax::arch::generator::{FpuConfig, FpuUnit};
use fpmax::bb::{run_energy_trace, window_bias_schedule, BbPolicy};
use fpmax::coordinator::serve_datapath;
use fpmax::energy::tech::Technology;
use fpmax::runtime::serve::{ServeConfig, ServeLoad, ServeQueue};
use fpmax::workloads::throughput::{OperandMix, OperandStream};

fn base_config(cfg: &FpuConfig, workers: usize, window: usize) -> ServeConfig {
    let mut scfg = ServeConfig::nominal(cfg, true).expect("nominal serve config");
    scfg.workers = workers;
    scfg.window_ops = window;
    scfg
}

#[test]
fn serve_results_match_direct_submission_order() {
    // Single producer, known submission order: every ticket's bits must
    // equal a direct batch run of the same triples.
    let cfg = FpuConfig::sp_fma();
    let unit = FpuUnit::generate(&cfg);
    let queue = ServeQueue::start(&unit, base_config(&cfg, 4, 256)).unwrap();
    let dp = UnitDatapath::new(&unit, Fidelity::WordSimd);
    let mut stream = OperandStream::new(cfg.precision, OperandMix::Anything, 99);
    let mut pending = Vec::new();
    for n in [1usize, 63, 700, 4_097, 256] {
        let triples = stream.batch(n);
        let mut want = vec![0u64; n];
        dp.fmac_batch(&triples, &mut want);
        let ticket = queue.submit(Fidelity::WordSimd, triples).unwrap();
        pending.push((want, ticket));
    }
    for (want, ticket) in pending {
        assert_eq!(ticket.wait().unwrap(), want);
    }
    let report = queue.finish().unwrap();
    assert_eq!(report.ops, 1 + 63 + 700 + 4_097 + 256);
    assert_eq!(report.submissions, 5);
    assert_eq!(report.crosscheck_mismatches, 0, "at {:?}", report.mismatch_indices);
    assert!(report.bb_consistent());
    assert_eq!(report.master.total_ops(), report.ops);
}

#[test]
fn prop_streamed_bb_equals_posthoc_all_tiers_and_interleavings() {
    // The tentpole property: for every fidelity tier, several seeds
    // (different submission-size sequences and operand streams), random
    // multi-producer interleavings and idle phases woven in, the
    // streamed controller's schedule AND energies are bit-identical to
    // the post-hoc pass over the master trace, the cross-check is
    // clean, and no activity is dropped.
    for (tier, total_ops) in [
        (Fidelity::GateLevel, 6_000usize),
        (Fidelity::WordLevel, 40_000),
        (Fidelity::WordSimd, 40_000),
    ] {
        let cfg = FpuConfig::sp_cma();
        let unit = FpuUnit::generate(&cfg);
        for (seed, duty) in [(1u64, 1.0f64), (2, 0.25), (3, 0.1)] {
            let load = ServeLoad {
                total_ops,
                producers: 3,
                sub_ops: 1_024,
                duty,
                seed,
            };
            let report =
                serve_datapath(&unit, tier, load, base_config(&cfg, 4, 512)).unwrap();
            assert_eq!(report.ops, total_ops as u64, "{tier:?} seed {seed}");
            assert_eq!(
                report.crosscheck_mismatches, 0,
                "{tier:?} seed {seed}: gate cross-check at {:?}",
                report.mismatch_indices
            );
            // Under any interleaving, the controller is exact on what it
            // received, and nothing was dropped on the way.
            assert!(report.received_schedule_matches, "{tier:?} seed {seed}");
            assert!(report.activity_preserved, "{tier:?} seed {seed}");
            // With the default ring the stream never overflows, so the
            // streamed schedule/energies equal the post-hoc pass on the
            // master trace bit for bit.
            assert_eq!(report.ring_coalesced, 0, "{tier:?} seed {seed}");
            assert!(
                report.schedule_matches && report.energy_matches,
                "{tier:?} seed {seed}: streamed BB diverged from post-hoc"
            );
            assert_eq!(
                report.streamed.schedule.len(),
                report.master.len(),
                "{tier:?} seed {seed}"
            );
            if duty < 1.0 {
                // Idle weave landed: occupancy near the requested duty.
                assert!(
                    report.occupancy < duty + 0.15,
                    "{tier:?} seed {seed}: occupancy {}",
                    report.occupancy
                );
            }
            if duty <= 0.1 {
                // Gaps this deep (≥ 9 idle slots per op) are far beyond
                // any plausible settle time, so the adaptive schedule
                // must actually re-bias at least one window.
                let (vbb_active, dropped) = {
                    let s = &report.streamed.schedule;
                    let hi = s.iter().cloned().fold(f64::MIN, f64::max);
                    (hi, s.iter().any(|&v| v < hi))
                };
                assert!(
                    dropped,
                    "{tier:?} seed {seed}: no window ever left vbb {vbb_active}"
                );
            }
        }
    }
}

#[test]
fn serve_overflow_degrades_without_losing_accounting() {
    // A 1-window ring under a multi-batch run WILL overflow whenever the
    // controller lags; whether a particular scheduling produces
    // coalescing is timing-dependent, but the accounting invariants must
    // hold either way — and the received-stream identity always holds.
    let cfg = FpuConfig::sp_fma();
    let unit = FpuUnit::generate(&cfg);
    let mut scfg = base_config(&cfg, 4, 128);
    scfg.ring_windows = 1;
    let load = ServeLoad { total_ops: 30_000, producers: 2, sub_ops: 512, duty: 0.5, seed: 7 };
    let report = serve_datapath(&unit, Fidelity::WordSimd, load, scfg).unwrap();
    assert_eq!(report.ops, 30_000);
    assert_eq!(report.crosscheck_mismatches, 0);
    // The two always-invariants.
    assert!(report.received_schedule_matches);
    assert!(report.activity_preserved, "overflow must never drop ops or toggles");
    // Whatever got merged, the controller saw every slot.
    assert_eq!(
        report.streamed.ops,
        report.master.total_ops(),
        "ring coalesced {} windows",
        report.ring_coalesced
    );
    // When nothing coalesced, full bit-identity follows.
    if report.ring_coalesced == 0 {
        assert!(report.schedule_matches && report.energy_matches);
    }
}

#[test]
fn serve_mixed_tiers_split_batches_and_stay_clean() {
    // Submissions at different tiers never coalesce into one batch, and
    // every tier's results are bit-identical to its own datapath.
    let cfg = FpuConfig::dp_fma();
    let unit = FpuUnit::generate(&cfg);
    let queue = ServeQueue::start(&unit, base_config(&cfg, 4, 256)).unwrap();
    let mut stream = OperandStream::new(cfg.precision, OperandMix::Anything, 5);
    let mut pending = Vec::new();
    for (i, tier) in [Fidelity::WordSimd, Fidelity::WordLevel, Fidelity::GateLevel]
        .into_iter()
        .cycle()
        .take(9)
        .enumerate()
    {
        let n = 300 + 37 * i;
        let triples = stream.batch(n);
        let dp = UnitDatapath::new(&unit, tier);
        let mut want = vec![0u64; n];
        dp.fmac_batch(&triples, &mut want);
        pending.push((want, queue.submit(tier, triples).unwrap()));
    }
    for (want, ticket) in pending {
        assert_eq!(ticket.wait().unwrap(), want);
    }
    let report = queue.finish().unwrap();
    assert_eq!(report.submissions, 9);
    assert_eq!(report.crosscheck_mismatches, 0);
    assert!(report.bb_consistent());
}

#[test]
fn streamed_energy_scores_like_offline_weave() {
    // End-to-end sanity: a low-duty serve run's streamed adaptive energy
    // equals run_energy_trace on the master trace (bit-identical — that
    // is schedule_matches/energy_matches), and the adaptive policy beats
    // the static one on the same master trace, reproducing the Fig. 4
    // recovery in the serving context.
    let cfg = FpuConfig::sp_cma();
    let unit = FpuUnit::generate(&cfg);
    let scfg = base_config(&cfg, 4, 1_024);
    let vdd = scfg.vdd;
    let policy = scfg.policy;
    let load = ServeLoad { total_ops: 50_000, producers: 2, sub_ops: 4_096, duty: 0.1, seed: 11 };
    let report = serve_datapath(&unit, Fidelity::WordSimd, load, scfg).unwrap();
    assert!(report.bb_consistent());
    let tech = Technology::fdsoi28();
    let adaptive = run_energy_trace(&unit, &tech, vdd, policy, &report.master).unwrap();
    assert_eq!(report.streamed.energy, adaptive);
    let static_e =
        run_energy_trace(&unit, &tech, vdd, BbPolicy::static_nominal(), &report.master)
            .unwrap();
    assert!(
        adaptive.pj_per_op < static_e.pj_per_op,
        "adaptive {} >= static {} at 10% duty",
        adaptive.pj_per_op,
        static_e.pj_per_op
    );
    // And the schedule really has idle-bias windows.
    let sched = window_bias_schedule(policy, &report.master);
    assert_eq!(report.streamed.schedule, sched);
}

#[test]
fn serve_handles_tiny_and_huge_submissions_mixed() {
    // The recalibration satellite, end-to-end: 64-op submissions mixed
    // with submissions far above the batch cap, all bit-exact.
    let cfg = FpuConfig::sp_fma();
    let unit = FpuUnit::generate(&cfg);
    let mut scfg = base_config(&cfg, 4, 512);
    scfg.max_batch_ops = 8_192;
    let queue = ServeQueue::start(&unit, scfg).unwrap();
    let dp = UnitDatapath::new(&unit, Fidelity::WordSimd);
    let mut stream = OperandStream::new(cfg.precision, OperandMix::Finite, 31);
    let mut pending = Vec::new();
    for n in [64usize, 100_000, 64, 64, 20_000, 64] {
        let triples = stream.batch(n);
        let mut want = vec![0u64; n];
        dp.fmac_batch(&triples, &mut want);
        pending.push((want, queue.submit(Fidelity::WordSimd, triples).unwrap()));
    }
    for (want, ticket) in pending {
        assert_eq!(ticket.wait().unwrap(), want);
    }
    let report = queue.finish().unwrap();
    assert_eq!(report.ops, (64 * 4 + 100_000 + 20_000) as u64);
    assert_eq!(report.crosscheck_mismatches, 0);
    assert!(report.bb_consistent());
}

#[test]
fn ticket_try_wait_and_wait_timeout() {
    let cfg = FpuConfig::sp_fma();
    let unit = FpuUnit::generate(&cfg);
    let queue = ServeQueue::start(&unit, base_config(&cfg, 2, 256)).unwrap();
    let dp = UnitDatapath::new(&unit, Fidelity::WordSimd);
    let mut stream = OperandStream::new(cfg.precision, OperandMix::Finite, 3);
    let triples = stream.batch(500);
    let mut want = vec![0u64; 500];
    dp.fmac_batch(&triples, &mut want);
    let ticket = queue.submit(Fidelity::WordSimd, triples).unwrap();
    // Poll until complete: a zero timeout returns Ok(None) while the
    // batch is in flight instead of blocking, then the bits exactly once.
    let mut got = None;
    for _ in 0..10_000 {
        if let Some(bits) = ticket
            .wait_timeout(std::time::Duration::from_millis(10))
            .expect("live dispatcher never errors tickets")
        {
            got = Some(bits);
            break;
        }
    }
    assert_eq!(got.expect("completed within the polling budget"), want);
    // After the bits were taken, a second poll errors distinctly — it is
    // never confusable with a legitimate empty result.
    assert!(ticket.try_wait().is_err(), "already-taken ticket must error, not hang or alias");
    let report = queue.finish().unwrap();
    assert_eq!(report.ops, 500);
    assert!(report.bb_consistent());
}

#[test]
fn dropped_dispatcher_errors_all_outstanding_tickets() {
    // The satellite regression: a dispatcher that dies mid-run must
    // error every outstanding ticket — queued AND mid-batch — instead of
    // hanging its producers, and the queue must reject new submissions.
    let cfg = FpuConfig::sp_fma();
    let unit = FpuUnit::generate(&cfg);
    let queue = ServeQueue::start(&unit, base_config(&cfg, 2, 256)).unwrap();
    let handle = queue.handle();
    let max_q = queue.max_queue_ops();
    let mut stream = OperandStream::new(cfg.precision, OperandMix::Finite, 21);

    // One submission the dispatcher may or may not reach before the
    // fault, then the fault, then submissions queued strictly behind it.
    let first = handle.submit(Fidelity::WordSimd, stream.batch(256), max_q).unwrap();
    handle.inject_fault().unwrap();
    let mut behind = Vec::new();
    for _ in 0..4 {
        // The dispatcher may already have hit the fault and closed the
        // queue — a submit-time error is the same contract, delivered
        // earlier.
        if let Ok(t) = handle.submit(Fidelity::WordSimd, stream.batch(100), max_q) {
            behind.push(t);
        }
    }

    // Everything behind the fault must resolve to an error in bounded
    // time — never a hang.
    for t in behind {
        let r = t.wait_timeout(std::time::Duration::from_secs(30));
        match r {
            Err(_) => {}
            Ok(Some(_)) => panic!("a submission behind the fault cannot have executed"),
            Ok(None) => panic!("ticket still pending: dispatcher death left it hanging"),
        }
    }
    // The first submission either completed cleanly (dispatcher got to
    // it first) or was errored by the teardown; both resolve.
    match first.wait_timeout(std::time::Duration::from_secs(30)) {
        Ok(Some(bits)) => assert_eq!(bits.len(), 256),
        Err(_) => {}
        Ok(None) => panic!("first ticket still pending after dispatcher death"),
    }
    // New submissions bounce off the closed queue...
    assert!(handle.submit(Fidelity::WordSimd, stream.batch(10), max_q).is_err());
    // ...and finish() reports the dispatcher death instead of a report.
    assert!(queue.finish().is_err());
}

#[test]
fn executor_recalibration_visible_through_serve_sized_runs() {
    // Companion to the engine-level regression test: the public
    // calibration surface behaves for the serve-shaped mixed sizes.
    let cfg = FpuConfig::sp_fma();
    let unit = FpuUnit::generate(&cfg);
    let dp = UnitDatapath::new(&unit, Fidelity::WordSimd);
    let exec = BatchExecutor::new(4);
    let mut stream = OperandStream::new(cfg.precision, OperandMix::Finite, 8);
    let big = stream.batch(400_000);
    let small = stream.batch(2_048);
    let mut out = vec![0u64; big.len()];
    exec.run_into(&dp, &big, &mut out).unwrap();
    assert_eq!(exec.calibrated_ops(), big.len());
    let mut out_small = vec![0u64; small.len()];
    exec.run_into(&dp, &small, &mut out_small).unwrap();
    assert_eq!(
        exec.calibrated_ops(),
        small.len(),
        "a ≥8×-smaller batch must recalibrate at its own scale"
    );
    for (i, t) in small.iter().enumerate() {
        assert_eq!(out_small[i], dp.fmac_one(t.a, t.b, t.c), "slot {i}");
    }
}
