//! Integration properties of the sharded serve router: Table-1
//! affinity routing, load-aware spill, per-shard isolation (calibration,
//! ring overflow, BB identity), and deterministic dispatch under seeded
//! load. Every shard's streamed body-bias accounting must stay
//! bit-identical to its own post-hoc single-shard path no matter what
//! its siblings are doing — that is the fleet contract.

use fpmax::arch::engine::{Datapath, Fidelity, UnitDatapath};
use fpmax::arch::fp::Precision;
use fpmax::arch::generator::FpuConfig;
use fpmax::bb::merge_run_energies;
use fpmax::coordinator::{serve_routed, RoutedLoad};
use fpmax::runtime::router::{
    RouterConfig, ServeRouter, ServiceClass, ShardSpec, WorkloadClass,
};
use fpmax::runtime::serve::ServeConfig;
use fpmax::workloads::throughput::{OperandMix, OperandStream};

fn spec(config: FpuConfig, tier: Fidelity, workers: usize, window: usize) -> ShardSpec {
    let mut serve = ServeConfig::nominal(&config, true).expect("nominal serve config");
    serve.workers = workers;
    serve.window_ops = window;
    ShardSpec { config, tier, serve }
}

fn table1_specs(tier: Fidelity, window: usize) -> Vec<ShardSpec> {
    FpuConfig::fpmax_units().into_iter().map(|c| spec(c, tier, 1, window)).collect()
}

/// The affinity shard index for `class` within `specs`.
fn affinity_shard(specs: &[ShardSpec], class: WorkloadClass) -> usize {
    specs
        .iter()
        .position(|s| {
            s.config.precision == class.precision
                && s.config.kind == class.service.affinity_kind()
        })
        .expect("full fleet has an affinity shard per class")
}

#[test]
fn static_policy_routes_every_class_to_its_table1_unit() {
    // The acceptance property: latency classes land on the CMA shards,
    // bulk classes on the FMA shards, per precision — misrouted == 0
    // with spill off — and every ticket's bits equal the landing unit's
    // own datapath (each shard computes its own Table-I semantics).
    let tier = Fidelity::WordSimd;
    let specs = table1_specs(tier, 256);
    let router = ServeRouter::start(&specs, RouterConfig::no_spill(4)).unwrap();
    let mut pending = Vec::new();
    for (ci, class) in WorkloadClass::ALL.into_iter().enumerate() {
        let expect_idx = affinity_shard(&specs, class);
        let dp = UnitDatapath::generate(&specs[expect_idx].config, tier);
        let mut stream =
            OperandStream::new(class.precision, OperandMix::Anything, 50 + ci as u64);
        for k in 0..3usize {
            let n = 200 + 61 * k;
            let triples = stream.batch(n);
            let mut want = vec![0u64; n];
            dp.fmac_batch(&triples, &mut want);
            let (idx, ticket) = router.submit(class, tier, triples).unwrap();
            assert_eq!(idx, expect_idx, "{} routed off-affinity", class.name());
            pending.push((want, ticket));
        }
    }
    for (want, ticket) in pending {
        assert_eq!(ticket.wait().unwrap(), want);
    }
    let report = router.finish().unwrap();
    assert_eq!(report.submissions, 12);
    assert_eq!(report.misrouted, 0, "static policy, no spill pressure");
    assert_eq!(report.spilled, 0);
    assert_eq!(report.misrouted_fraction(), 0.0);
    assert_eq!(report.crosscheck_mismatches(), 0);
    assert!(report.bb_gate_ok(), "every shard's streamed BB must match post-hoc");
    // The per-class shard histogram is concentrated on the affinity
    // diagonal.
    let hist = report.class_histogram();
    for class in WorkloadClass::ALL {
        let expect_idx = affinity_shard(&specs, class);
        for (si, _) in report.shards.iter().enumerate() {
            let want = if si == expect_idx { 3 } else { 0 };
            assert_eq!(
                hist[class.index()][si],
                want,
                "class {} shard {si}",
                class.name()
            );
        }
    }
    let total: u64 = report.shards.iter().map(|s| s.report.ops).sum();
    assert_eq!(report.ops, total);
}

#[test]
fn overloaded_shard_spills_to_its_compatible_sibling() {
    // Load-aware spill: pile large latency-class batches onto the SP CMA
    // shard; once its in-flight pressure crosses the threshold, the
    // router diverts to the less-loaded SP FMA sibling. A spilled
    // submission is computed in the receiving unit's own semantics
    // (fused vs cascade), so expectations follow the landing shard.
    let tier = Fidelity::WordSimd;
    let specs = vec![
        spec(FpuConfig::sp_cma(), tier, 1, 512),
        spec(FpuConfig::sp_fma(), tier, 1, 512),
    ];
    let router = ServeRouter::start(&specs, RouterConfig::with_spill(2, 1_000)).unwrap();
    let class = WorkloadClass { precision: Precision::Single, service: ServiceClass::Latency };
    let dps =
        [UnitDatapath::generate(&specs[0].config, tier), UnitDatapath::generate(&specs[1].config, tier)];
    // Precompute all batches + both units' expectations BEFORE the first
    // submit, so the submissions land back-to-back while the single
    // worker is still chewing on the first batch.
    let mut stream = OperandStream::new(Precision::Single, OperandMix::Finite, 4);
    const N: usize = 150_000;
    let prepared: Vec<_> = (0..4)
        .map(|_| {
            let triples = stream.batch(N);
            let mut wants = [vec![0u64; N], vec![0u64; N]];
            dps[0].fmac_batch(&triples, &mut wants[0]);
            dps[1].fmac_batch(&triples, &mut wants[1]);
            (triples, wants)
        })
        .collect();
    let mut pending = Vec::new();
    for (i, (triples, wants)) in prepared.into_iter().enumerate() {
        let (idx, ticket) = router.submit(class, tier, triples).unwrap();
        if i == 0 {
            // The first dispatch just landed N unresolved ops on the
            // affinity shard — the pressure probe the spill policy reads.
            assert!(
                router.shard_pressure(idx) >= N,
                "in-flight pressure must be visible immediately after submit"
            );
        }
        let [cma, fma] = wants;
        pending.push((idx, if idx == 0 { cma } else { fma }, ticket));
    }
    let mut landed = [0u64; 2];
    for (idx, want, ticket) in pending {
        assert_eq!(ticket.wait().unwrap(), want, "shard {idx} result diverged");
        landed[idx] += 1;
    }
    let report = router.finish().unwrap();
    assert!(report.spilled >= 1, "overload never spilled: landed {landed:?}");
    assert_eq!(report.spilled, report.misrouted, "all off-affinity traffic here is spill");
    assert_eq!(report.shards[1].spilled_in, report.spilled);
    assert_eq!(report.shards[0].spilled_in, 0);
    assert_eq!(report.crosscheck_mismatches(), 0);
    assert!(report.bb_gate_ok());
    assert_eq!(report.ops, 4 * N as u64);
}

#[test]
fn routed_duty_weave_rebiases_every_shard() {
    // All-shards-idle duty weave: every class's producer weaves idle
    // phases onto its affinity shard, so all four adaptive controllers
    // see deep gaps and actually re-bias — and the fleet energy is the
    // exact sum of the per-shard streamed accounting.
    let tier = Fidelity::WordSimd;
    let specs = table1_specs(tier, 512);
    let load =
        RoutedLoad { total_ops: 40_000, producers_per_class: 1, sub_ops: 1_024, duty: 0.1, seed: 5 };
    let report = serve_routed(&specs, RouterConfig::no_spill(4), tier, load).unwrap();
    assert_eq!(report.ops, 40_000);
    assert_eq!(report.misrouted, 0);
    assert_eq!(report.crosscheck_mismatches(), 0);
    for s in &report.shards {
        assert!(s.report.ops > 0, "{}: no work landed", s.unit);
        assert!(
            s.report.occupancy < 0.25,
            "{}: idle weave missing (occupancy {})",
            s.unit,
            s.report.occupancy
        );
        assert_eq!(s.report.ring_coalesced, 0, "{}", s.unit);
        assert!(
            s.report.schedule_matches && s.report.energy_matches,
            "{}: streamed BB diverged from post-hoc",
            s.unit
        );
        // 10% duty ⇒ gaps of ~9 idle slots per op — far beyond any
        // plausible settle time, so at least one window must drop bias.
        let sched = &s.report.streamed.schedule;
        let hi = sched.iter().cloned().fold(f64::MIN, f64::max);
        assert!(
            sched.iter().any(|&v| v < hi),
            "{}: adaptive schedule never re-biased",
            s.unit
        );
    }
    // Fleet accounting is the exact sum of the shards' streamed runs.
    let manual = merge_run_energies(report.shards.iter().map(|s| &s.report.streamed.energy));
    assert_eq!(report.fleet_energy.ops, manual.ops);
    assert_eq!(report.fleet_energy.dynamic_pj, manual.dynamic_pj);
    assert_eq!(report.fleet_energy.leakage_pj, manual.leakage_pj);
    assert_eq!(report.fleet_energy.transition_pj, manual.transition_pj);
    let streamed_total: u64 = report.shards.iter().map(|s| s.report.streamed.ops).sum();
    assert_eq!(report.fleet_energy.ops, streamed_total);
}

#[test]
fn ring_overflow_on_one_shard_leaves_siblings_bit_identical() {
    // Shard isolation under overflow: a 1-window ring on the SP FMA
    // shard may coalesce under load, but its siblings' streams must stay
    // pristine — full streamed-vs-post-hoc bit identity — and even the
    // overflowing shard never drops accounting.
    let tier = Fidelity::WordSimd;
    let mut specs = table1_specs(tier, 128);
    let squeezed = affinity_shard(
        &specs,
        WorkloadClass { precision: Precision::Single, service: ServiceClass::Bulk },
    );
    specs[squeezed].serve.ring_windows = 1;
    let load =
        RoutedLoad { total_ops: 60_000, producers_per_class: 1, sub_ops: 512, duty: 0.5, seed: 7 };
    let report = serve_routed(&specs, RouterConfig::no_spill(4), tier, load).unwrap();
    assert_eq!(report.ops, 60_000);
    assert_eq!(report.crosscheck_mismatches(), 0);
    for (si, s) in report.shards.iter().enumerate() {
        // The always-invariants, every shard.
        assert!(s.report.received_schedule_matches, "{}", s.unit);
        assert!(s.report.activity_preserved, "{}: accounting dropped", s.unit);
        assert!(s.report.bb_gate_ok(), "{}", s.unit);
        if si != squeezed {
            // Siblings are untouched by the squeezed shard's overflow.
            assert_eq!(s.report.ring_coalesced, 0, "{}: sibling ring overflowed", s.unit);
            assert!(
                s.report.schedule_matches && s.report.energy_matches,
                "{}: sibling lost bit identity",
                s.unit
            );
        }
    }
}

#[test]
fn routing_is_deterministic_under_seeded_load() {
    // Two identical seeded runs through the pure static policy must
    // produce identical dispatch decisions: same per-shard submission
    // histograms, same per-shard op totals.
    let tier = Fidelity::WordLevel;
    let load =
        RoutedLoad { total_ops: 30_000, producers_per_class: 1, sub_ops: 512, duty: 1.0, seed: 9 };
    let run = || {
        let specs = table1_specs(tier, 512);
        serve_routed(&specs, RouterConfig::no_spill(4), tier, load).unwrap()
    };
    let a = run();
    let b = run();
    assert_eq!(a.submissions, b.submissions);
    assert_eq!(a.ops, b.ops);
    assert_eq!(a.misrouted, 0);
    assert_eq!(b.misrouted, 0);
    for (sa, sb) in a.shards.iter().zip(&b.shards) {
        assert_eq!(sa.unit, sb.unit);
        assert_eq!(sa.class_counts, sb.class_counts, "{}", sa.unit);
        assert_eq!(sa.report.ops, sb.report.ops, "{}", sa.unit);
        assert_eq!(sa.report.submissions, sb.report.submissions, "{}", sa.unit);
    }
}

#[test]
fn transprecision_fleet_routes_format_tagged_classes() {
    // The transprecision acceptance property: a mixed small-format fleet
    // (fp16 CMA + fp16/bf16/fp8 FMA shards) dispatches format-tagged
    // WorkloadClass submissions to their affinity shards with
    // misrouted == 0 under the static policy, and every ticket's bits
    // equal the landing unit's own datapath in that unit's format.
    let tier = Fidelity::WordSimd;
    let specs = vec![
        spec(FpuConfig::cma_of(Precision::Half), tier, 1, 256),
        spec(FpuConfig::fma_of(Precision::Half), tier, 1, 256),
        spec(FpuConfig::fma_of(Precision::Bfloat16), tier, 1, 256),
        spec(FpuConfig::fma_of(Precision::Fp8E4M3), tier, 1, 256),
        spec(FpuConfig::fma_of(Precision::Fp8E5M2), tier, 1, 256),
    ];
    let classes = [
        WorkloadClass { precision: Precision::Half, service: ServiceClass::Latency },
        WorkloadClass { precision: Precision::Half, service: ServiceClass::Bulk },
        WorkloadClass { precision: Precision::Bfloat16, service: ServiceClass::Bulk },
        WorkloadClass { precision: Precision::Fp8E4M3, service: ServiceClass::Bulk },
        WorkloadClass { precision: Precision::Fp8E5M2, service: ServiceClass::Bulk },
    ];
    let router = ServeRouter::start(&specs, RouterConfig::no_spill(specs.len())).unwrap();
    let mut pending = Vec::new();
    for (ci, class) in classes.into_iter().enumerate() {
        let expect_idx = affinity_shard(&specs, class);
        let dp = UnitDatapath::generate(&specs[expect_idx].config, tier);
        let mut stream =
            OperandStream::new(class.precision, OperandMix::Anything, 90 + ci as u64);
        for k in 0..3usize {
            let n = 200 + 61 * k;
            let triples = stream.batch(n);
            let mut want = vec![0u64; n];
            dp.fmac_batch(&triples, &mut want);
            let (idx, ticket) = router.submit(class, tier, triples).unwrap();
            assert_eq!(idx, expect_idx, "{} routed off-affinity", class.name());
            pending.push((want, ticket));
        }
    }
    for (want, ticket) in pending {
        assert_eq!(ticket.wait().unwrap(), want);
    }
    let report = router.finish().unwrap();
    assert_eq!(report.submissions, 15);
    assert_eq!(report.misrouted, 0, "static policy, format-tagged classes");
    assert_eq!(report.spilled, 0);
    assert_eq!(report.crosscheck_mismatches(), 0);
    assert!(report.bb_gate_ok());
    // The format-tagged rows of the class histogram concentrate on the
    // affinity diagonal; the SP/DP rows stay empty.
    let hist = report.class_histogram();
    for class in classes {
        let expect_idx = affinity_shard(&specs, class);
        for si in 0..specs.len() {
            let want = if si == expect_idx { 3 } else { 0 };
            assert_eq!(hist[class.index()][si], want, "class {} shard {si}", class.name());
        }
    }
    for class in WorkloadClass::ALL {
        assert!(
            hist[class.index()].iter().all(|&c| c == 0),
            "SP/DP class {} saw traffic in a small-format fleet",
            class.name()
        );
    }
}

#[test]
fn mixed_tier_shards_isolate_chunk_calibration() {
    // The per-shard calibration satellite, end-to-end: the same unit
    // served at gate and word-simd tiers as two shards (per-op costs
    // ~an order of magnitude apart), huge lane-tier submissions
    // interleaved with tiny gate-tier ones. Each shard owns its
    // executor, so neither tier's chunk hint can poison the other's —
    // pinned here by exactness and clean per-shard reports at every
    // scale.
    let specs = vec![
        spec(FpuConfig::sp_fma(), Fidelity::GateLevel, 1, 256),
        spec(FpuConfig::sp_fma(), Fidelity::WordSimd, 1, 512),
    ];
    let router = ServeRouter::start(&specs, RouterConfig::no_spill(2)).unwrap();
    let class = WorkloadClass { precision: Precision::Single, service: ServiceClass::Bulk };
    // Bits are tier-invariant, so one golden covers both shards.
    let dp = UnitDatapath::generate(&FpuConfig::sp_fma(), Fidelity::WordLevel);
    let mut stream = OperandStream::new(Precision::Single, OperandMix::Finite, 13);
    let mut pending = Vec::new();
    for (tier, n, expect_idx) in [
        (Fidelity::WordSimd, 120_000usize, 1usize),
        (Fidelity::GateLevel, 64, 0),
        (Fidelity::WordSimd, 64, 1),
        (Fidelity::GateLevel, 2_000, 0),
        (Fidelity::WordSimd, 80_000, 1),
        (Fidelity::GateLevel, 64, 0),
    ] {
        let triples = stream.batch(n);
        let mut want = vec![0u64; n];
        dp.fmac_batch(&triples, &mut want);
        let (idx, ticket) = router.submit(class, tier, triples).unwrap();
        assert_eq!(idx, expect_idx, "tier {tier:?} landed on the wrong shard");
        pending.push((want, ticket));
    }
    for (want, ticket) in pending {
        assert_eq!(ticket.wait().unwrap(), want);
    }
    let report = router.finish().unwrap();
    assert_eq!(report.ops, (120_000 + 64 + 64 + 2_000 + 80_000 + 64) as u64);
    assert_eq!(report.shards[0].report.ops, (64 + 2_000 + 64) as u64);
    assert_eq!(report.shards[1].report.ops, (120_000 + 64 + 80_000) as u64);
    assert_eq!(report.crosscheck_mismatches(), 0);
    assert!(report.bb_gate_ok());
}
