//! Body-bias scenario (the paper's Fig. 4 story): run a bursty 10%-
//! utilization workload on the SP CMA under three bias policies and
//! show where the energy goes — dynamic, leakage, and bias-transition.
//!
//! Run: `cargo run --release --example body_bias`

use fpmax::arch::generator::{FpuConfig, FpuUnit};
use fpmax::bb::controller::{run_energy, BbPolicy};
use fpmax::energy::tech::Technology;
use fpmax::report::TextTable;
use fpmax::workloads::utilization::UtilizationProfile;

fn main() -> fpmax::Result<()> {
    let tech = Technology::fdsoi28();
    let unit = FpuUnit::generate(&FpuConfig::sp_cma());
    let vdd = 0.6; // near the energy-optimal point of Fig. 4

    println!("Body-bias policies on SP CMA @ {vdd} V, 10% utilization\n");

    let profiles = [
        ("100% utilization", UtilizationProfile::full(1_000_000)),
        ("10%, 10k-cycle bursts", UtilizationProfile::duty(0.1, 10_000, 1_000_000)),
        ("10%, 500-cycle bursts", UtilizationProfile::duty(0.1, 500, 1_000_000)),
        ("10%, bursty (random)", UtilizationProfile::bursty(0.1, 5_000, 1_000_000, 42)),
    ];
    let policies = [
        ("static fwd BB (1.2V)", BbPolicy::static_nominal()),
        ("static no BB", BbPolicy::Static { vbb: 0.0 }),
        ("adaptive BB", BbPolicy::adaptive_nominal(1.0)),
    ];

    let mut t = TextTable::new(vec![
        "workload", "policy", "pJ/op", "dyn pJ/op", "leak pJ/op", "transition pJ/op",
    ]);
    for (wname, prof) in &profiles {
        for (pname, policy) in &policies {
            let e = run_energy(&unit, &tech, vdd, *policy, prof).expect("operable");
            let ops = e.ops.max(1) as f64;
            t.row(vec![
                wname.to_string(),
                pname.to_string(),
                format!("{:.1}", e.pj_per_op),
                format!("{:.1}", e.dynamic_pj / ops),
                format!("{:.1}", e.leakage_pj / ops),
                format!("{:.2}", e.transition_pj / ops),
            ]);
        }
    }
    t.print();

    println!(
        "\nReading the table: at 10% utilization the statically forward-biased unit\n\
         pays several× the full-utilization energy per op (leakage across the idle\n\
         gaps); the adaptive controller drops the bias during long gaps and recovers\n\
         most of it — unless bursts are so short the wells never finish settling\n\
         (500-cycle row). This is the paper's Fig. 4 in mechanism and magnitude."
    );
    Ok(())
}
