//! End-to-end driver — the repository's full-system validation run.
//!
//! Exercises every layer on a real workload and proves they compose:
//!
//! 1. **workload**: deterministic operand streams (finite + specials);
//! 2. **chip** (Fig. 5): JTAG-load stimulus RAMs, run all four
//!    generated FPUs at speed from the instruction sequencer, read back
//!    over JTAG;
//! 3. **golden model**: every chip result checked bit-for-bit against
//!    the softfloat spec (fused semantics for FMAs, cascade for CMAs);
//! 4. **AOT artifacts** (L1/L2): the same streams through the compiled
//!    Pallas/JAX HLO via PJRT, cross-checked against the golden model;
//! 5. **physics**: the activity (toggle counts) from the artifact feeds
//!    the energy model to report the run's estimated silicon energy.
//!
//! Run: `make artifacts && cargo run --release --example chip_selftest`
//! The numbers land in EXPERIMENTS.md §E6.

use std::time::Instant;

use fpmax::arch::generator::{FpuConfig, FpuUnit};
use fpmax::arch::rounding::RoundMode;
use fpmax::chip::{
    expected_result, FpMaxChip, Instruction, Op, UnitSel, BANK_PROGRAM, BANK_RESULT, BANK_STIM_A,
    BANK_STIM_B, BANK_STIM_C,
};
use fpmax::coordinator;
use fpmax::energy::power::evaluate;
use fpmax::energy::tech::Technology;
use fpmax::runtime::Runtime;
use fpmax::timing::nominal_op;
use fpmax::workloads::throughput::{OperandMix, OperandStream};

const OPS_PER_UNIT: usize = 65_536;
const RAM_DEPTH: usize = 1024;

fn main() -> fpmax::Result<()> {
    let t_start = Instant::now();
    let tech = Technology::fdsoi28();
    let mut chip = FpMaxChip::new(RAM_DEPTH);

    println!("=== FPMax end-to-end self-test ({OPS_PER_UNIT} ops/unit) ===\n");

    // ---- Phase 1+2+3: chip at-speed runs vs golden model -------------
    let mut grand_ops = 0u64;
    let mut grand_cycles = 0u64;
    for (sel, cfg) in [
        (UnitSel::DpCma, FpuConfig::dp_cma()),
        (UnitSel::DpFma, FpuConfig::dp_fma()),
        (UnitSel::SpCma, FpuConfig::sp_cma()),
        (UnitSel::SpFma, FpuConfig::sp_fma()),
    ] {
        let mut mismatches = 0usize;
        let mut jtag_tck = 0u64;
        let t0 = Instant::now();
        // Mix finite and anything-goes operands 3:1.
        let mut fin = OperandStream::new(cfg.precision, OperandMix::Finite, 42);
        let mut any = OperandStream::new(cfg.precision, OperandMix::Anything, 43);
        let mut done = 0usize;
        while done < OPS_PER_UNIT {
            let n = RAM_DEPTH.min(OPS_PER_UNIT - done);
            let triples: Vec<_> = (0..n)
                .map(|i| if i % 4 == 3 { any.next_triple() } else { fin.next_triple() })
                .collect();
            let a: Vec<u64> = triples.iter().map(|t| t.a).collect();
            let b: Vec<u64> = triples.iter().map(|t| t.b).collect();
            let c: Vec<u64> = triples.iter().map(|t| t.c).collect();
            {
                let mut port = chip.jtag();
                port.load_bank(BANK_STIM_A, &a)?;
                port.load_bank(BANK_STIM_B, &b)?;
                port.load_bank(BANK_STIM_C, &c)?;
                let prog = [Instruction::fmac_burst(sel, 0, n as u16).encode() as u64, 0];
                port.load_bank(BANK_PROGRAM, &prog)?;
                jtag_tck += port.tck_cycles;
            }
            let stats = chip.run()?;
            grand_ops += stats.ops;
            grand_cycles += stats.cycles;
            let results = chip.jtag().read_bank(BANK_RESULT, n)?;
            let unit = chip.unit(sel);
            for i in 0..n {
                let want = expected_result(unit, RoundMode::NearestEven, a[i], b[i], c[i], Op::Fmac);
                // NaN payloads may differ; compare through decode.
                let fmt = unit.format;
                use fpmax::arch::fp::{decode, Class};
                let ok = results[i] == want
                    || (decode(fmt, results[i]).class == Class::Nan
                        && decode(fmt, want).class == Class::Nan);
                if !ok {
                    mismatches += 1;
                }
            }
            done += n;
        }
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "{:<7}: {OPS_PER_UNIT} at-speed ops, {mismatches} mismatches, {:.2} Mops/s wall, {:.1}k JTAG TCK",
            format!("{sel:?}"),
            OPS_PER_UNIT as f64 / dt / 1e6,
            jtag_tck as f64 / 1e3,
        );
        anyhow::ensure!(mismatches == 0, "{sel:?}: chip diverged from golden model");
    }
    println!("\nchip total: {grand_ops} ops in {grand_cycles} at-speed cycles");

    // ---- Phase 4: AOT artifacts through PJRT --------------------------
    let rt = Runtime::cpu("artifacts")?;
    println!("\nPJRT platform: {}", rt.platform());
    let mut artifact_toggles = Vec::new();
    for (name, cfg) in [("sp_fmac", FpuConfig::sp_fma()), ("dp_fmac", FpuConfig::dp_fma())] {
        let artifact = rt.load_fmac(name, cfg.precision)?;
        let unit = FpuUnit::generate(&cfg);
        let mut stream = OperandStream::new(cfg.precision, OperandMix::Finite, 0xF00D);
        let triples = stream.batch(OPS_PER_UNIT);
        let r = coordinator::verify_batch(&unit, &artifact, &triples, workers())?;
        println!(
            "{name}: {} ops  artifact-vs-golden {} mism  datapath {} mism  {:.2} Mops/s PJRT / {:.2} Mops/s rust",
            r.ops,
            r.artifact_mismatches.len(),
            r.datapath_mismatches.len(),
            r.ops as f64 / r.pjrt_secs / 1e6,
            r.ops as f64 / r.rust_secs / 1e6,
        );
        anyhow::ensure!(r.clean(), "{name}: three-layer cross-check failed");
        artifact_toggles.push((cfg, r.artifact_toggles, r.ops));
    }

    // ---- Phase 5: energy accounting from measured activity ------------
    println!("\nestimated silicon energy for this run (activity-scaled):");
    for (cfg, toggles, ops) in artifact_toggles {
        let unit = FpuUnit::generate(&cfg);
        let eff = evaluate(&unit, &tech, nominal_op(&cfg), 1.0).expect("nominal");
        // Toggle-based activity scale: measured result-bus toggles per op
        // vs the half-width random baseline.
        let width = cfg.precision.format().width() as f64;
        let activity = (toggles as f64 / ops as f64) / (width / 2.0);
        let e_op = fpmax::energy::components::unit_cost(&unit)
            .dyn_energy_pj(nominal_op(&cfg).vdd, activity.clamp(0.2, 1.5));
        println!(
            "  {}: {:.2} toggles/bit-op → activity {:.2} → {:.1} pJ/op dynamic ({:.1} µJ for the run; nominal-activity model: {:.1} pJ/op)",
            cfg.name(),
            toggles as f64 / ops as f64 / width,
            activity,
            e_op,
            e_op * ops as f64 * 1e-6,
            2.0 * eff.pj_per_flop,
        );
    }

    println!(
        "\nSELFTEST PASS in {:.1}s: workload → chip (JTAG+at-speed) → golden model →\n\
         AOT Pallas/JAX artifact (PJRT) → energy model, all layers agree.",
        t_start.elapsed().as_secs_f64()
    );
    Ok(())
}

fn workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}
