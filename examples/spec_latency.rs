//! SPEC-latency scenario (the paper's Fig. 2(c) workload): simulate the
//! SPEC-FP-like suite on all four units and a few hypothetical variants,
//! reporting average latency penalty and benchmarked delay.
//!
//! Run: `cargo run --release --example spec_latency`

use fpmax::arch::generator::{FpuConfig, FpuUnit};
use fpmax::energy::tech::Technology;
use fpmax::pipesim::{benchmarked_delay_ns, simulate, LatencyModel};
use fpmax::report::TextTable;
use fpmax::timing::{nominal_op, timing};
use fpmax::workloads::specfp::Profile;

fn main() -> fpmax::Result<()> {
    let tech = Technology::fdsoi28();
    let suite = Profile::suite();
    let ops = 50_000;

    println!("SPEC-FP-like latency study ({} profiles × {ops} ops)\n", suite.len());

    let mut variants: Vec<(String, FpuConfig)> = FpuConfig::fpmax_units()
        .iter()
        .map(|c| (c.name(), *c))
        .collect();
    // The paper's comparison FMAs.
    let mut fma5 = FpuConfig::dp_fma();
    fma5.stages = 5;
    variants.push(("DP FMA-5 w/ fwd".into(), fma5));
    let mut fma5_nofwd = fma5;
    fma5_nofwd.forwarding = false;
    variants.push(("DP FMA-5 w/o fwd".into(), fma5_nofwd));

    let mut table = TextTable::new(vec![
        "unit", "avg penalty", "cyc/FLOP", "cycle ps", "bench delay ns",
    ]);
    for (name, cfg) in &variants {
        let unit = FpuUnit::generate(cfg);
        let lat = LatencyModel::of(&unit);
        let mean_pen: f64 = suite
            .iter()
            .map(|p| simulate(&lat, &p.generate(ops, 42)).avg_penalty)
            .sum::<f64>()
            / suite.len() as f64;
        let t = timing(cfg, &tech, nominal_op(cfg)).expect("nominal");
        let sim = simulate(&lat, &suite[0].generate(ops, 42));
        let _ = sim;
        let delay = t.cycle_ps * (1.0 + mean_pen) / 1000.0;
        table.row(vec![
            name.clone(),
            format!("{mean_pen:.3}"),
            format!("{:.3}", 1.0 + mean_pen),
            format!("{:.0}", t.cycle_ps),
            format!("{delay:.2}"),
        ]);
        let _ = benchmarked_delay_ns(t.cycle_ps, &simulate(&lat, &suite[0].generate(1000, 1)));
    }
    table.print();

    println!("\nPer-profile penalties (DP CMA vs DP FMA-5 w/ fwd):");
    let cma = LatencyModel::of(&FpuUnit::generate(&FpuConfig::dp_cma()));
    let fma = LatencyModel::of(&FpuUnit::generate(&fma5));
    let mut t2 = TextTable::new(vec!["profile", "CMA", "FMA", "CMA advantage"]);
    for p in &suite {
        let trace = p.generate(ops, 42);
        let pc = simulate(&cma, &trace).avg_penalty;
        let pf = simulate(&fma, &trace).avg_penalty;
        t2.row(vec![
            p.name.to_string(),
            format!("{pc:.3}"),
            format!("{pf:.3}"),
            format!("{:.0}%", (1.0 - pc / pf) * 100.0),
        ]);
    }
    t2.print();
    Ok(())
}
