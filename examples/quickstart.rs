//! Quickstart: generate the four FPMax units, run a few FMACs through
//! each bit-accurate datapath, and print the Table-I summary numbers.
//!
//! Run: `cargo run --release --example quickstart`

use fpmax::arch::generator::{FpuConfig, FpuUnit};
use fpmax::energy::power::evaluate;
use fpmax::energy::tech::Technology;
use fpmax::timing::nominal_op;

fn main() -> fpmax::Result<()> {
    let tech = Technology::fdsoi28();

    println!("FPMax quickstart — the four fabricated units\n");
    for cfg in FpuConfig::fpmax_units() {
        // 1. Generate the unit (FPGen's job).
        let unit = FpuUnit::generate(&cfg);
        let s = unit.structure();

        // 2. Run a computation through the bit-accurate datapath.
        let (a, b, c) = match cfg.precision {
            fpmax::arch::fp::Precision::Single => (
                1.5f32.to_bits() as u64,
                (-2.25f32).to_bits() as u64,
                10.0f32.to_bits() as u64,
            ),
            fpmax::arch::fp::Precision::Double => {
                (1.5f64.to_bits(), (-2.25f64).to_bits(), 10.0f64.to_bits())
            }
        };
        let r = unit.fmac(a, b, c);
        let shown = match cfg.precision {
            fpmax::arch::fp::Precision::Single => f32::from_bits(r.bits as u32) as f64,
            fpmax::arch::fp::Precision::Double => f64::from_bits(r.bits),
        };

        // 3. Evaluate the physical model at the chip's nominal point.
        let eff = evaluate(&unit, &tech, nominal_op(&cfg), 1.0).expect("nominal point");

        println!("{}:", cfg.name());
        println!("  structure : {} stages, Booth-{}, {} tree, {} PPs, {} tree cells",
                 cfg.stages, cfg.booth.name(), cfg.tree.name(), s.pp_count, s.tree_cells);
        println!("  numerics  : 1.5 × −2.25 + 10 = {shown}");
        println!("  physics   : {:.2} GHz, {:.1} mW, {:.0} GFLOPS/W, {:.0} GFLOPS/mm²",
                 eff.freq_ghz, eff.power.total_mw(), eff.gflops_per_w, eff.gflops_per_mm2);
        println!("  latencies : full {} cyc, →acc {} cyc, →mul {} cyc\n",
                 unit.latency_full(), unit.latency_to_add_input(), unit.latency_to_mul_input());
    }
    println!("(reproduce the full evaluation: `fpmax table1|table2|fig2c|fig3|fig4`)");
    Ok(())
}
