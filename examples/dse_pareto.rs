//! Design-space exploration scenario: run the FPGen sweep for a chosen
//! precision/organization, extract the Pareto frontier, and show where
//! the fabricated FPMax designs landed — the workflow behind Fig. 3's
//! triangle-marked curve.
//!
//! Run: `cargo run --release --example dse_pareto`

use fpmax::arch::fp::Precision;
use fpmax::arch::generator::{FpuConfig, FpuKind};
use fpmax::dse::{arch_sweep, frontier, Objective};
use fpmax::energy::tech::{OperatingPoint, Technology};
use fpmax::report::TextTable;

fn main() -> fpmax::Result<()> {
    let tech = Technology::fdsoi28();
    let op = OperatingPoint::new(1.0, 0.0); // FPGen's fixed-voltage sweep

    for (precision, kind, fabricated) in [
        (Precision::Single, FpuKind::Fma, FpuConfig::sp_fma()),
        (Precision::Double, FpuKind::Fma, FpuConfig::dp_fma()),
        (Precision::Single, FpuKind::Cma, FpuConfig::sp_cma()),
        (Precision::Double, FpuKind::Cma, FpuConfig::dp_cma()),
    ] {
        let pts = arch_sweep(precision, kind, &tech, op);
        let front = frontier(&pts);
        println!(
            "\n=== {} {} space: {} designs, {} Pareto-optimal ===\n",
            precision.name().to_uppercase(),
            kind.name(),
            pts.len(),
            front.len()
        );
        let mut t = TextTable::new(vec![
            "", "stages", "booth", "tree", "GFLOPS/mm²", "pJ/FLOP",
        ]);
        for &i in &front {
            let p = &pts[i];
            let is_fab = p.config.stages == fabricated.stages
                && p.config.booth == fabricated.booth
                && p.config.tree == fabricated.tree;
            t.row(vec![
                if is_fab { "★ fabricated" } else { "" }.to_string(),
                p.config.stages.to_string(),
                p.config.booth.name().to_string(),
                p.config.tree.name().to_string(),
                format!("{:.1}", p.perf()),
                format!("{:.2}", p.energy()),
            ]);
        }
        t.print();

        // Where is the fabricated point relative to the frontier?
        let fab = pts.iter().find(|p| {
            p.config.stages == fabricated.stages
                && p.config.booth == fabricated.booth
                && p.config.tree == fabricated.tree
        });
        if let Some(fab) = fab {
            let on_front = front.iter().any(|&i| std::ptr::eq(&pts[i], fab));
            println!(
                "\nfabricated {}: {:.1} GFLOPS/mm² at {:.2} pJ/FLOP ({})",
                fabricated.name(),
                fab.perf(),
                fab.energy(),
                if on_front { "ON the frontier" } else { "near the frontier" }
            );
        }
    }
    Ok(())
}
